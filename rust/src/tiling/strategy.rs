//! Pluggable tiling strategies — the selection layer behind
//! [`crate::coordinator::Planner`].
//!
//! The paper's claim is that the associativity-lattice model *predicts*
//! good tilings rather than discovering them empirically. This module
//! makes that claim continuously testable: the lattice selector is one
//! [`TilingStrategy`] among several, and the startup race
//! ([`crate::codegen::autotune::race_strategy_rates`]) measures every
//! registered strategy's proposed [`LevelPlan`] on the real packed
//! engine, records the per-(kernel, dtype, shape-class) winner in the
//! [`Registry`](crate::runtime::Registry), and the planner dispatches it.
//!
//! Three strategies ship:
//!
//! * [`Lattice`] — the paper's model-driven path ([`super::level_plan`]):
//!   seed `mc×kc` from the lattice-model tile search against the L2
//!   spec, grow to capacity, size `nc`/`m3×n3` against the L3 slice.
//! * [`CacheOblivious`] — PCOT-style recursive halving of the dominant
//!   GEMM axis down to a microkernel-multiple base case. Consults **no
//!   cache parameters at all**: the blocking depends only on the shape
//!   and the register-tile quanta.
//! * [`LatencyCurve`] — picks `mc/kc/nc` from measured per-working-set
//!   latency knee points (a pointer-chase over doubling working sets,
//!   calibrated once per process): the knees stand in for the L2/L3
//!   capacities, so the blocking follows the *measured* memory
//!   hierarchy instead of a named spec.
//!
//! Every strategy returns a [`LevelPlan`], and a `LevelPlan` only
//! changes *blocking* — each output element still accumulates its `kc`
//! slices in ascending-`k0` order — so rival strategies' plans execute
//! bitwise-identically on exact (integer-valued) data; the differential
//! suite pins this.

use std::sync::OnceLock;
use std::time::Instant;

use crate::cache::CacheSpec;
use crate::codegen::microkernel::{MR, NR};
use crate::codegen::runplan::GemmForm;
use crate::domain::Kernel;

use super::selection::{level_plan, round_up_mult, LevelPlan};

/// Identity of one registered tiling strategy — what the registry
/// records winners as and `Plan.describe()` reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// The associativity-lattice model-driven selector (the paper).
    Lattice,
    /// PCOT-style recursive halving; no cache parameters consulted.
    Oblivious,
    /// Measured latency-knee capacities driving the capacity heuristic.
    Latency,
}

impl StrategyKind {
    /// Every raced strategy, in deterministic race order. The lattice
    /// selector is first — it is the incumbent under
    /// [`pick_winner`](crate::codegen::autotune::pick_winner)'s
    /// tie-keeps-default rule, so a rival must beat it by the upgrade
    /// margin to dethrone it.
    pub const RACED: [StrategyKind; 3] = [
        StrategyKind::Lattice,
        StrategyKind::Oblivious,
        StrategyKind::Latency,
    ];

    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Lattice => "lattice",
            StrategyKind::Oblivious => "oblivious",
            StrategyKind::Latency => "latency",
        }
    }

    /// Parse a CLI spelling (`lattice`/`oblivious`/`latency`).
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s {
            "lattice" => Some(StrategyKind::Lattice),
            "oblivious" => Some(StrategyKind::Oblivious),
            "latency" => Some(StrategyKind::Latency),
            _ => None,
        }
    }
}

/// The planner-facing strategy selection: `auto` dispatches the
/// registry-recorded race winner (lattice when no race has run), a
/// fixed kind overrides it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum StrategyChoice {
    /// Dispatch the recorded per-(kernel, dtype, shape-class) winner.
    #[default]
    Auto,
    /// Force one strategy regardless of the recorded winner.
    Fixed(StrategyKind),
}

impl StrategyChoice {
    /// Parse a CLI spelling (`lattice`/`oblivious`/`latency`/`auto`).
    pub fn parse(s: &str) -> Option<StrategyChoice> {
        if s == "auto" {
            return Some(StrategyChoice::Auto);
        }
        StrategyKind::parse(s).map(StrategyChoice::Fixed)
    }

    pub fn name(self) -> &'static str {
        match self {
            StrategyChoice::Auto => "auto",
            StrategyChoice::Fixed(k) => k.name(),
        }
    }
}

/// The shape-class bucket strategy winners are recorded under: per-axis
/// log₂ buckets of the GEMM-form `(m, n, k)` extents — the same
/// bit-width classing the planner's shard hash uses, so one race result
/// covers every shape that blocks alike.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeClass {
    pub m: u8,
    pub n: u8,
    pub k: u8,
}

fn bucket(d: usize) -> u8 {
    (usize::BITS - d.max(1).leading_zeros()) as u8
}

impl ShapeClass {
    /// Class of a GEMM-form `(m, n, k)` extent triple.
    pub fn of((m, n, k): (usize, usize, usize)) -> ShapeClass {
        ShapeClass {
            m: bucket(m),
            n: bucket(n),
            k: bucket(k),
        }
    }

    /// Class of a kernel: its GEMM-form extents, or `(points, 1, 1)`
    /// for kernels outside the GEMM class.
    pub fn of_kernel(kernel: &Kernel) -> ShapeClass {
        match GemmForm::of(kernel) {
            Some(gf) => ShapeClass::of((gf.m, gf.n, gf.k)),
            None => {
                let points = kernel
                    .extents()
                    .iter()
                    .map(|&e| e.max(1) as usize)
                    .product::<usize>();
                ShapeClass::of((points, 1, 1))
            }
        }
    }
}

/// A tiling-selection strategy: propose the three-level blocking
/// ([`LevelPlan`]) for one kernel instance. Implementations must be
/// pure functions of their inputs plus their own calibration state —
/// the race measures each proposal on the packed engine, and the
/// planner re-invokes the winner at plan time.
pub trait TilingStrategy: Sync {
    /// The registry identity of this strategy.
    fn kind(&self) -> StrategyKind;

    /// Human-readable name (the registry / `Plan.describe()` spelling).
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Propose the macro blocking for `kernel` with GEMM-form `extents`
    /// `(m, n, k)` and the already-selected L1 tile. `l2`/`l3` are the
    /// modelled cache specs — strategies are free to ignore them
    /// ([`CacheOblivious`] consults nothing, [`LatencyCurve`] its own
    /// measured knees). `sample_classes` bounds any model sampling the
    /// strategy performs.
    fn propose(
        &self,
        kernel: &Kernel,
        extents: (usize, usize, usize),
        l1_tile: (usize, usize, usize),
        l2: &CacheSpec,
        l3: Option<&CacheSpec>,
        sample_classes: usize,
    ) -> LevelPlan;
}

/// The paper's model-driven selector as a strategy: exactly
/// [`super::level_plan`] (lattice-model tile search seeding `mc×kc`,
/// capacity growth, L3-sized `nc`/super-bands).
#[derive(Clone, Copy, Debug, Default)]
pub struct Lattice;

impl TilingStrategy for Lattice {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Lattice
    }

    fn propose(
        &self,
        kernel: &Kernel,
        extents: (usize, usize, usize),
        l1_tile: (usize, usize, usize),
        l2: &CacheSpec,
        l3: Option<&CacheSpec>,
        sample_classes: usize,
    ) -> LevelPlan {
        level_plan(kernel, extents, l1_tile, l2, l3, sample_classes)
    }
}

/// PCOT-style cache-oblivious blocking: starting from the whole
/// (quantum-rounded) GEMM box, recursively halve the dominant axis —
/// the one farthest above its base case, measured in base-case units —
/// until every axis is at or below a fixed microkernel-multiple base
/// case. No cache parameters are consulted anywhere: the resulting
/// `mc×kc×nc` depends only on the shape and the register-tile quanta,
/// which is exactly the cache-oblivious bet (recursive halving fits
/// *every* level of any hierarchy eventually). The super-band level is
/// a single covering band — an L3-sized band would be a cache
/// parameter.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheOblivious;

/// Base-case sizes in quanta: the recursion stops once an axis is at or
/// below `16` row/column quanta (128 rows at `MR = 8`) or 256 reduction
/// steps — a footprint small enough for any L1/L2 on the planet, per
/// the cache-oblivious argument.
const OBLIVIOUS_BASE_QUANTA: usize = 16;
const OBLIVIOUS_BASE_K: usize = 256;

impl TilingStrategy for CacheOblivious {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Oblivious
    }

    fn propose(
        &self,
        _kernel: &Kernel,
        extents: (usize, usize, usize),
        l1_tile: (usize, usize, usize),
        _l2: &CacheSpec,
        _l3: Option<&CacheSpec>,
        _sample_classes: usize,
    ) -> LevelPlan {
        let (m, n, k) = extents;
        // form-aware quanta as in the capacity heuristic: degenerate
        // GEMM dimensions block at their true extent
        let mq = if m >= MR { MR } else { 1 };
        let nq = if n >= NR { NR } else { 1 };
        let base_m = OBLIVIOUS_BASE_QUANTA * mq;
        let base_n = OBLIVIOUS_BASE_QUANTA * nq;
        let base_k = OBLIVIOUS_BASE_K;
        let mut mc = round_up_mult(m, mq);
        let mut nc = round_up_mult(n, nq);
        let mut kc = k.max(1);
        // halve the dominant axis (largest in base-case units) until all
        // axes hit their base case; each halving strictly shrinks the
        // axis, so the loop terminates
        loop {
            let rm = if mc > base_m { mc.div_ceil(base_m) } else { 0 };
            let rn = if nc > base_n { nc.div_ceil(base_n) } else { 0 };
            let rk = if kc > base_k { kc.div_ceil(base_k) } else { 0 };
            let dominant = rm.max(rn).max(rk);
            if dominant == 0 {
                break;
            }
            if rm == dominant {
                mc = round_up_mult(mc / 2, mq);
            } else if rk == dominant {
                kc = (kc / 2).max(1);
            } else {
                nc = round_up_mult(nc / 2, nq);
            }
        }
        LevelPlan {
            l1_tile,
            mc,
            kc,
            nc,
            // a single covering super-band: sizing bands against an L3
            // slice would be a cache parameter
            m3: round_up_mult(m, mc.max(1)),
            n3: round_up_mult(n, nc.max(1)),
        }
    }
}

/// Latency-based blocking: a one-shot pointer-chase over doubling
/// working sets finds the latency *knees* — the largest working set
/// before each access-latency jump — and the second and third knees
/// stand in for the L2 and L3 capacities in the capacity heuristic
/// ([`LevelPlan::heuristic`]). Calibration runs once per process
/// ([`LatencyCurve::calibrated`]); a machine whose curve shows fewer
/// than three knees falls back to the Haswell constants per missing
/// level.
#[derive(Clone, Copy, Debug)]
pub struct LatencyCurve {
    /// Measured L2-equivalent knee capacity in bytes.
    pub l2_bytes: usize,
    /// Measured L3-equivalent knee capacity in bytes.
    pub l3_bytes: usize,
}

impl LatencyCurve {
    /// A curve with explicit knee capacities (tests, replaying a saved
    /// calibration).
    pub fn with_capacities(l2_bytes: usize, l3_bytes: usize) -> LatencyCurve {
        let l2_bytes = l2_bytes.clamp(64 * 1024, 8 * 1024 * 1024);
        let l3_bytes = l3_bytes.clamp(2 * l2_bytes, 64 * 1024 * 1024);
        LatencyCurve { l2_bytes, l3_bytes }
    }

    /// The process-wide calibrated curve: measured once on first use
    /// (tens of milliseconds), shared afterwards.
    pub fn calibrated() -> &'static LatencyCurve {
        static CURVE: OnceLock<LatencyCurve> = OnceLock::new();
        CURVE.get_or_init(|| {
            let knees = measure_latency_knees();
            let l2 = knees
                .get(1)
                .copied()
                .unwrap_or(CacheSpec::HASWELL_L2.capacity);
            let l3 = knees
                .get(2)
                .copied()
                .unwrap_or(CacheSpec::HASWELL_L3_SLICE.capacity);
            LatencyCurve::with_capacities(l2, l3)
        })
    }
}

impl TilingStrategy for LatencyCurve {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Latency
    }

    fn propose(
        &self,
        kernel: &Kernel,
        extents: (usize, usize, usize),
        l1_tile: (usize, usize, usize),
        _l2: &CacheSpec,
        _l3: Option<&CacheSpec>,
        _sample_classes: usize,
    ) -> LevelPlan {
        let elem = kernel.operand(0).table.elem().max(1);
        // synthetic specs carrying the measured knee capacities; line
        // size and ways only matter to the lattice model, which this
        // strategy does not consult
        let l2 = CacheSpec::new(self.l2_bytes, 64, 8, 2);
        let l3 = CacheSpec::new(self.l3_bytes, 64, 16, 3);
        LevelPlan::heuristic(l1_tile, extents, elem, &l2, Some(&l3))
    }
}

/// Measure the latency curve: for each doubling working-set size, chase
/// a full-cycle random permutation (every load depends on the last, so
/// the measured time is pure latency) and record the per-access cost;
/// return the knee capacities — each size *before* a ≥1.5× latency
/// jump. Deterministic permutation, bounded accesses: the whole sweep
/// is tens of milliseconds.
fn measure_latency_knees() -> Vec<usize> {
    let sizes: Vec<usize> = (0..11).map(|i| (16 * 1024) << i).collect(); // 16 KiB … 16 MiB
    let mut knees = Vec::new();
    let mut prev: Option<(usize, f64)> = None;
    for &bytes in &sizes {
        let lat = chase_latency(bytes);
        if let Some((pbytes, plat)) = prev {
            if lat > plat * 1.5 {
                knees.push(pbytes);
            }
        }
        prev = Some((bytes, lat));
    }
    knees
}

/// Nanoseconds per dependent load over a `bytes`-sized working set.
fn chase_latency(bytes: usize) -> f64 {
    let len = (bytes / std::mem::size_of::<usize>()).max(2);
    // Sattolo's algorithm: a single cycle through all slots, so the
    // chase touches the whole working set before repeating
    let mut next: Vec<usize> = (0..len).collect();
    let mut s = 0x9E37_79B9_7F4A_7C15u64 ^ bytes as u64;
    let mut rnd = move |bound: usize| {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        (s % bound as u64) as usize
    };
    for i in (1..len).rev() {
        next.swap(i, rnd(i));
    }
    let accesses = 1usize << 15;
    // warm the set once
    let mut p = 0usize;
    for _ in 0..len.min(accesses) {
        p = next[p];
    }
    let t = Instant::now();
    for _ in 0..accesses {
        p = next[p];
    }
    let ns = t.elapsed().as_nanos() as f64;
    assert!(p < len); // keep the chase observable
    ns / accesses as f64
}

/// Resolve a strategy identity to its (process-wide, calibrated where
/// needed) implementation.
pub fn strategy_impl(kind: StrategyKind) -> &'static dyn TilingStrategy {
    match kind {
        StrategyKind::Lattice => &Lattice,
        StrategyKind::Oblivious => &CacheOblivious,
        StrategyKind::Latency => LatencyCurve::calibrated(),
    }
}

/// Every raced strategy implementation, in [`StrategyKind::RACED`]
/// order (lattice first: the incumbent of the winner rule).
pub fn raced_strategies() -> [&'static dyn TilingStrategy; 3] {
    [&Lattice, &CacheOblivious, LatencyCurve::calibrated()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::ops;

    #[test]
    fn kinds_parse_and_name_round_trip() {
        for kind in StrategyKind::RACED {
            assert_eq!(StrategyKind::parse(kind.name()), Some(kind));
            assert_eq!(
                StrategyChoice::parse(kind.name()),
                Some(StrategyChoice::Fixed(kind))
            );
            assert_eq!(strategy_impl(kind).kind(), kind);
            assert_eq!(strategy_impl(kind).name(), kind.name());
        }
        assert_eq!(StrategyChoice::parse("auto"), Some(StrategyChoice::Auto));
        assert_eq!(StrategyChoice::Auto.name(), "auto");
        assert_eq!(StrategyKind::parse("rect"), None);
        assert_eq!(StrategyChoice::default(), StrategyChoice::Auto);
    }

    #[test]
    fn shape_classes_bucket_by_bit_width() {
        assert_eq!(ShapeClass::of((128, 128, 128)), ShapeClass::of((255, 129, 255)));
        assert_ne!(ShapeClass::of((128, 128, 128)), ShapeClass::of((256, 128, 128)));
        assert_eq!(ShapeClass::of((0, 1, 1)), ShapeClass::of((1, 1, 1)));
        // kernel classing reads the GEMM form: matmul(m, k, n) → (m, n, k)
        let a = ShapeClass::of_kernel(&ops::matmul(64, 32, 16, 8, 0));
        assert_eq!(a, ShapeClass::of((64, 16, 32)));
        // degenerate forms class by their dot shape
        let c = ShapeClass::of_kernel(&ops::convolution(100, 8, 0));
        assert_eq!(c.m, bucket(1));
    }

    #[test]
    fn oblivious_halves_to_the_base_case_without_cache_specs() {
        let k = ops::matmul(1024, 2048, 512, 8, 0);
        let lp = CacheOblivious.propose(
            &k,
            (1024, 512, 2048),
            (8, 8, 8),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            0,
        );
        assert!(lp.mc <= OBLIVIOUS_BASE_QUANTA * MR && lp.mc % MR == 0 && lp.mc > 0);
        assert!(lp.nc <= OBLIVIOUS_BASE_QUANTA * NR && lp.nc % NR == 0 && lp.nc > 0);
        assert!(lp.kc <= OBLIVIOUS_BASE_K && lp.kc > 0);
        // single covering super-band — no L3 parameter consulted
        assert!(lp.m3 >= 1024 && lp.m3 % lp.mc == 0);
        assert!(lp.n3 >= 512 && lp.n3 % lp.nc == 0);
        // identical inputs, identical plan: the strategy is pure
        let again = CacheOblivious.propose(
            &k,
            (1024, 512, 2048),
            (8, 8, 8),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            0,
        );
        assert_eq!(lp, again);
    }

    #[test]
    fn oblivious_blocks_degenerate_forms_at_their_extent() {
        let k = ops::convolution(5000, 8, 0);
        let lp = CacheOblivious.propose(
            &k,
            (1, 1, 5000),
            (8, 1, 1),
            &CacheSpec::HASWELL_L2,
            None,
            0,
        );
        assert_eq!((lp.mc, lp.nc), (1, 1));
        assert!(lp.kc <= OBLIVIOUS_BASE_K);
    }

    #[test]
    fn latency_curve_clamps_and_plans_like_the_heuristic() {
        // degenerate measurements clamp into a sane band, and the plan
        // is exactly the capacity heuristic at the knee capacities
        let c = LatencyCurve::with_capacities(1, 1);
        assert_eq!(c.l2_bytes, 64 * 1024);
        assert_eq!(c.l3_bytes, 2 * c.l2_bytes);
        let k = ops::matmul(256, 256, 256, 8, 0);
        let lp = c.propose(
            &k,
            (256, 256, 256),
            (8, 8, 8),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            0,
        );
        let want = LevelPlan::heuristic(
            (8, 8, 8),
            (256, 256, 256),
            8,
            &CacheSpec::new(c.l2_bytes, 64, 8, 2),
            Some(&CacheSpec::new(c.l3_bytes, 64, 16, 3)),
        );
        assert_eq!(lp, want);
        // the process-wide calibration resolves and is stable
        let a = LatencyCurve::calibrated();
        let b = LatencyCurve::calibrated();
        assert_eq!((a.l2_bytes, a.l3_bytes), (b.l2_bytes, b.l3_bytes));
        assert!(a.l2_bytes >= 64 * 1024 && a.l3_bytes >= 2 * a.l2_bytes);
    }
}
