//! Tile selection — §4.0.4.
//!
//! Two selectors, as in the paper:
//!
//! * the **common-sense / `K−1` rule**: lattice tiles can be constructed
//!   *without counting lattice points* — a fundamental parallelepiped of
//!   the (LLL-reduced) conflict lattice contains exactly one lattice point,
//!   so scaling basis vectors by integer factors with product `κ` yields a
//!   tile with exactly `κ` points. The paper observes `κ = K−1` performs
//!   well. Remaining loop dimensions are tiled rectangularly with sizes
//!   induced by the lattice tile.
//! * the **model-driven search**: score a small candidate set with the
//!   (sampled) miss model of Eq. (4) and keep the best — the paper's
//!   envisaged hybrid.

use crate::cache::CacheSpec;
use crate::codegen::microkernel::{MR, NR};
use crate::codegen::runplan::GemmForm;
use crate::conflict::{ConflictAnalysis, MissModel, ModelCounts};
use crate::domain::Kernel;
use crate::lattice::{IMat, Lattice};

use super::schedule::TiledSchedule;
use super::tile::TileBasis;

/// A three-level tiling decision: the L1 tile the paper's selector
/// picks, driven inside BLIS-style `mc×kc×nc` macro blocks sized for the
/// outer cache levels, which in turn partition into `m3×n3` **L3
/// super-bands** — the unit the parallel scheduler hands to workers and
/// the row range whose packed slice must stay L3-slice-resident.
/// Executed by [`crate::codegen::executor::run_macro`] /
/// [`crate::codegen::run_parallel_macro`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LevelPlan {
    /// L1 tile footprint `(ti, tj, tk)` in loop space (i, j, kk).
    pub l1_tile: (usize, usize, usize),
    /// Macro-block rows of the packed B block (`MR`-aligned).
    pub mc: usize,
    /// Macro-block k depth shared by the packed B and C blocks.
    pub kc: usize,
    /// Macro-block output columns (`NR`-aligned).
    pub nc: usize,
    /// Super-band rows (`mc`-aligned): the row range one worker packs and
    /// streams per reduction slice. Values ≥ the GEMM row extent mean a
    /// single row super-band (the pre-L3 flat schedule).
    pub m3: usize,
    /// Super-band output columns (`nc`-aligned). Values ≥ the GEMM
    /// column extent mean a single column super-band.
    pub n3: usize,
}

impl LevelPlan {
    /// A plan with no L3 super-band level: one super-band covers the
    /// whole output (the flat two-level schedule). For tests and callers
    /// that size the macro level by hand.
    pub fn flat(
        l1_tile: (usize, usize, usize),
        mc: usize,
        kc: usize,
        nc: usize,
    ) -> LevelPlan {
        LevelPlan {
            l1_tile,
            mc,
            kc,
            nc,
            m3: usize::MAX,
            n3: usize::MAX,
        }
    }

    /// Capacity-driven macro shape: `mc×kc` sized to half of `l2` so the
    /// packed B block stays L2-resident while streaming, `nc` sized so
    /// the packed C block fits half an `l3` slice (whole output width
    /// when no L3 is modelled), and the `m3×n3` super-band sized so one
    /// worker's packed row slice (`m3×kc`, a quarter of the slice) plus
    /// its output band (`m3×n3`, half the slice) stay L3-slice-resident
    /// across the reduction. `extents` is the kernel's **GEMM-form**
    /// `(m, n, k)` — convolution and scalar product pass `(1, 1, k)`,
    /// Kronecker its factor products — so degenerate dimensions get
    /// degenerate blocks (`mc = 1` when `m = 1`) instead of the matmul
    /// `MR`/`NR` defaults. `elem` is the kernel's element size in bytes
    /// (4 for f32, 8 for f64) — halving it doubles the elements a level
    /// holds, so f32 plans legitimately get 2× the block area.
    pub fn heuristic(
        l1_tile: (usize, usize, usize),
        extents: (usize, usize, usize),
        elem: usize,
        l2: &CacheSpec,
        l3: Option<&CacheSpec>,
    ) -> LevelPlan {
        let (m, n, k) = extents;
        let elem = elem.max(1);
        // form-aware alignment quanta: a dimension the GEMM form reduces
        // to (almost) nothing is blocked at its true extent, not padded
        // to a register-tile multiple
        let mq = if m >= MR { MR } else { 1 };
        let nq = if n >= NR { NR } else { 1 };
        let half_l2 = (l2.capacity / (2 * elem)).max(mq);
        // deep k first: kc is the only k blocking between the macro level
        // and the registers, and it amortizes the A write-back
        let kc = k.clamp(1, 256.max(l1_tile.2));
        let mc = round_down_mult(half_l2 / kc, mq).clamp(mq, round_up_mult(m, mq));
        let nc = match l3 {
            Some(l3) => {
                let cap = (l3.capacity / (2 * elem * kc)).max(nq);
                round_down_mult(cap, nq).clamp(nq, round_up_mult(n, nq))
            }
            None => round_up_mult(n, nq),
        };
        let (m3, n3) = super_band_heuristic((m, n), (mc, kc, nc), elem, l3);
        LevelPlan {
            l1_tile,
            mc,
            kc,
            nc,
            m3,
            n3,
        }
    }
}

/// Size the `m3×n3` super-band against an L3 slice: the packed row slice
/// (`m3×kc`) gets a quarter of the slice, the output band (`m3×n3`) half,
/// leaving headroom for the streaming column bands. Without an L3 spec a
/// single super-band covers the output (the flat schedule).
fn super_band_heuristic(
    (m, n): (usize, usize),
    (mc, kc, nc): (usize, usize, usize),
    elem: usize,
    l3: Option<&CacheSpec>,
) -> (usize, usize) {
    match l3 {
        Some(l3) => {
            let quarter = (l3.capacity / (4 * elem)).max(1);
            let half = (l3.capacity / (2 * elem)).max(1);
            let m3 = round_down_mult(quarter / kc.max(1), mc).clamp(mc, round_up_mult(m, mc));
            let n3 = round_down_mult(half / m3, nc).clamp(nc, round_up_mult(n, nc));
            (m3, n3)
        }
        None => (round_up_mult(m, mc), round_up_mult(n, nc)),
    }
}

/// Largest multiple of `q` that is ≤ `v` (0 when `v < q`).
pub(crate) fn round_down_mult(v: usize, q: usize) -> usize {
    (v / q) * q
}

/// Smallest multiple of `q` that is ≥ `v` (at least one quantum).
pub(crate) fn round_up_mult(v: usize, q: usize) -> usize {
    v.div_ceil(q).max(1) * q
}

/// Model-driven macro shape: run the existing selector against the
/// *outer*-level spec (`l2`) to seed the `mc×kc` block — the same K−1
/// lattice rule + sampled-model search the L1 tile comes from, just
/// against the next level's associativity lattice — then grow the seed
/// to the level's capacity (the selector's candidate set is bounded, so
/// growth keeps its aspect ratio). `extents` is the true GEMM-form
/// `(m, n, k)` to block, which may exceed the (possibly shrunk) model
/// kernel's box.
///
/// The selection is **kernel-aware**: the winning tile's extents are read
/// off the kernel's own [`GemmForm`] axis groups, so convolution and
/// scalar product seed `(mc, kc)` from their degenerate `1×1×k` dot form
/// (the whole tile is reduction depth), Kronecker from its swapped
/// `{k,l}×{i,j}` outer-product form (`kc = 1` — there is no reduction to
/// deepen), and matmul from `{i}×{j}×{kk}` — instead of every kernel
/// reusing matmul's loop-axis positions. The element size comes from the
/// kernel's own tables, so an f32 kernel (4-byte elements) both reshapes
/// the conflict lattices the seed is selected against *and* doubles the
/// elements each level's capacity holds — the selector sees the dtype
/// end to end. The `m3×n3` super-band level is sized against `l3` like
/// [`LevelPlan::heuristic`].
pub fn level_plan(
    kernel: &Kernel,
    extents: (usize, usize, usize),
    l1_tile: (usize, usize, usize),
    l2: &CacheSpec,
    l3: Option<&CacheSpec>,
    sample_classes: usize,
) -> LevelPlan {
    let (m, n, k) = extents;
    let gf = GemmForm::of(kernel);
    let ranked = select(kernel, l2, sample_classes);
    let seed = ranked
        .first()
        .map(|p| {
            let b = p.schedule.basis();
            let ext = |i: usize| -> usize {
                (0..b.dim())
                    .map(|j| b.basis()[(i, j)].unsigned_abs() as usize)
                    .sum::<usize>()
                    .max(1)
            };
            match &gf {
                // the winning tile's extents over the kernel's own GEMM
                // row/reduction axis groups — not matmul's loop positions
                Some(gf) => {
                    let group = |axes: &[usize]| -> usize {
                        axes.iter().map(|&t| ext(t)).product::<usize>().max(1)
                    };
                    (group(&gf.row_axes), group(&gf.red_axes))
                }
                None => {
                    let d = b.dim();
                    (ext(0), if d > 2 { ext(2) } else { 1 })
                }
            }
        })
        .unwrap_or((l1_tile.0.max(1), l1_tile.2.max(1)));
    let elem = kernel.operand(0).table.elem().max(1);
    // form-aware quanta, as in the heuristic: degenerate GEMM dimensions
    // are blocked at their true extent
    let mq = if m >= MR { MR } else { 1 };
    let nq = if n >= NR { NR } else { 1 };
    let half_l2 = (l2.capacity / (2 * elem)).max(mq);
    let (mut mc, mut kc) = seed;
    mc = round_up_mult(mc, mq);
    let mc_cap = round_up_mult(m, mq);
    while 2 * kc <= k && mc * 2 * kc <= half_l2 {
        kc *= 2;
    }
    while mc + mq <= mc_cap && (mc + mq) * kc <= half_l2 {
        mc += mq;
    }
    kc = kc.min(k.max(1));
    mc = mc.min(mc_cap).max(mq);
    let nc = match l3 {
        Some(l3) => {
            let cap = (l3.capacity / (2 * elem * kc)).max(nq);
            round_down_mult(cap, nq).clamp(nq, round_up_mult(n, nq))
        }
        None => round_up_mult(n, nq),
    };
    let (m3, n3) = super_band_heuristic((m, n), (mc, kc, nc), elem, l3);
    LevelPlan {
        l1_tile,
        mc,
        kc,
        nc,
        m3,
        n3,
    }
}

/// A fully specified tiling decision for a kernel.
#[derive(Clone, Debug)]
pub struct TilingPlan {
    /// Human-readable tag, e.g. `lattice[B]x7+j32` or `rect 32x32x32`.
    pub name: String,
    /// The loop-space schedule to execute.
    pub schedule: TiledSchedule,
    /// Which operand's conflict lattice shaped the tile (None = rect).
    pub lattice_operand: Option<usize>,
    /// Model prediction, if the plan was scored.
    pub predicted: Option<ModelCounts>,
}

/// All integer factorizations of `k` into `parts` ordered factors.
fn factorizations(k: i128, parts: usize) -> Vec<Vec<i128>> {
    if parts == 1 {
        return vec![vec![k]];
    }
    let mut out = Vec::new();
    let mut d = 1;
    while d * d <= k || d <= k {
        if k % d == 0 {
            for mut rest in factorizations(k / d, parts - 1) {
                let mut v = vec![d];
                v.append(&mut rest);
                out.push(v);
            }
        }
        d += 1;
        if d > k {
            break;
        }
    }
    out
}

/// Scale the columns of an LLL-reduced lattice basis so the parallelepiped
/// contains exactly `kappa` lattice points, choosing the factor split that
/// keeps the tile's bounding box smallest (best fit inside the operand).
pub fn scaled_lattice_tile(l: &Lattice, kappa: i128, dims: &[i64]) -> TileBasis {
    assert!(kappa >= 1);
    let reduced = l.lll();
    let b = reduced.basis();
    let d = b.cols();
    let mut best: Option<(i128, TileBasis)> = None;
    for factors in factorizations(kappa, d) {
        let mut m = b.clone();
        for (j, &f) in factors.iter().enumerate() {
            for i in 0..d {
                m[(i, j)] *= f;
            }
        }
        let t = TileBasis::from_cols(m);
        // bounding-box score: penalize extents beyond the operand dims
        let mut score = 0i128;
        let mut fits = true;
        for i in 0..d {
            let ext: i128 = (0..d).map(|j| t.basis()[(i, j)].abs()).sum();
            score += ext * ext;
            if ext > dims[i] as i128 {
                fits = false;
            }
        }
        if !fits {
            score *= 1024; // strongly prefer tiles inside the operand
        }
        if best.as_ref().is_none_or(|(s, _)| score < *s) {
            best = Some((score, t));
        }
    }
    best.expect("at least one factorization").1
}

/// Snap a rectangular tile's microkernel-facing inner dimensions to
/// microkernel multiples: dim 0 (the unit-stride rows fed to the register
/// tile) to a multiple of `MR`, dim 1 (the output columns) to a multiple
/// of `NR`. Tiles that are multiples keep the register blocks full, so
/// the boundary (clipped) kernel only ever runs on the domain boundary,
/// not inside every tile.
///
/// The snap quanta are the *base* geometry classes on purpose: every
/// candidate of the 2-D autotune grid has `mr ∈ {8, 16}` and
/// `nr ∈ {4, 6, 8, 12}`, so an `MR`-multiple row extent is also covered
/// by whole-or-edge 16-row panels (a 16-row winner runs one full panel
/// per pair of 8-row quanta plus at most one edge panel), and `NR = 4`
/// divides the f32 wide widths (8, 12) exactly. Snapping to the largest
/// candidate instead would shrink legal tile space for the common 8-row
/// shapes without making tall dispatch any fuller.
pub fn snap_to_microkernel(tile: &[i64], extents: &[i64]) -> Vec<i64> {
    let mut t = tile.to_vec();
    if !t.is_empty() {
        t[0] = snap_dim(t[0], MR as i64, extents[0]);
    }
    if t.len() > 1 {
        t[1] = snap_dim(t[1], NR as i64, extents[1]);
    }
    t
}

/// Largest multiple of `quantum` that is ≤ `size` (at least one quantum),
/// clamped into the loop extent; degenerates gracefully when the extent is
/// smaller than one quantum.
fn snap_dim(size: i64, quantum: i64, extent: i64) -> i64 {
    if extent < quantum {
        return size.clamp(1, extent);
    }
    let max_mult = (extent / quantum) * quantum;
    ((size / quantum) * quantum).clamp(quantum, max_mult)
}

/// Embed an operand-space tile into the loop space: operand dimension `r`
/// must be a pure selection of one loop variable (true for every Table-1
/// access except Kronecker's output). Non-operand loop variables get
/// rectangular tile sizes from `other_sizes` (indexed by loop var).
///
/// Returns `None` if the access is not a pure selection.
pub fn embed_operand_tile(
    kernel: &Kernel,
    op_idx: usize,
    op_tile: &TileBasis,
    other_sizes: &[i64],
) -> Option<TileBasis> {
    let op = kernel.operand(op_idx);
    let n = kernel.n_free();
    assert_eq!(other_sizes.len(), n);
    // find the loop var each operand dim selects
    let mut sel = Vec::with_capacity(op.access.rank());
    for r in 0..op.access.rank() {
        let row = &op.access.coef[r];
        let mut var = None;
        for (v, &a) in row.iter().enumerate() {
            match a {
                0 => {}
                1 if var.is_none() && op.access.cons[r] == 0 => var = Some(v),
                _ => return None,
            }
        }
        sel.push(var?);
    }
    // distinct vars required
    {
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        if s.len() != sel.len() {
            return None;
        }
    }
    let mut m = IMat::zeros(n, n);
    let mut col = 0usize;
    // operand tile generators, lifted
    for j in 0..op_tile.dim() {
        for r in 0..op_tile.dim() {
            m[(sel[r], col)] = op_tile.basis()[(r, j)];
        }
        col += 1;
    }
    // remaining loop vars: rectangular
    for v in 0..n {
        if !sel.contains(&v) {
            m[(v, col)] = other_sizes[v].max(1) as i128;
            col += 1;
        }
    }
    Some(TileBasis::from_cols(m))
}

/// The paper's `K−1` common-sense selector: lattice-tile `op_idx` with
/// `κ = K−1` conflict-lattice points; tile the remaining loops
/// rectangularly with sizes induced by the lattice tile's extent.
pub fn k_minus_one_plan(kernel: &Kernel, spec: &CacheSpec, op_idx: usize) -> Option<TilingPlan> {
    plan_with_kappa(kernel, spec, op_idx, spec.ways as i128 - 1)
}

/// Generalized `κ`-point lattice plan (the paper's `[K−α, K+β]` band).
pub fn plan_with_kappa(
    kernel: &Kernel,
    spec: &CacheSpec,
    op_idx: usize,
    kappa: i128,
) -> Option<TilingPlan> {
    let analysis = ConflictAnalysis::new(kernel, spec);
    let oc = &analysis.operands[op_idx];
    let dims = kernel.operand(op_idx).table.dims();
    let op_tile = scaled_lattice_tile(&oc.operand_lattice, kappa.max(1), dims);
    // induced rectangular sizes for remaining loops: geometric mean of the
    // lattice tile extents, clamped to the loop extent
    let d = op_tile.dim();
    let mean_ext: i128 = (0..d)
        .map(|i| (0..d).map(|j| op_tile.basis()[(i, j)].abs()).sum::<i128>())
        .max()
        .unwrap_or(8)
        .max(1);
    let other: Vec<i64> = kernel
        .extents()
        .iter()
        .map(|&e| (mean_ext as i64).min(e).max(1))
        .collect();
    // snap the rectangular (non-lattice) loop dims to microkernel
    // multiples so the executor's register blocks stay full
    let other = snap_to_microkernel(&other, kernel.extents());
    let loop_basis = embed_operand_tile(kernel, op_idx, &op_tile, &other)?;
    Some(TilingPlan {
        name: format!(
            "lattice[{}]x{} ({}pts)",
            kernel.operand(op_idx).table.name(),
            mean_ext,
            kappa
        ),
        schedule: TiledSchedule::new(loop_basis),
        lattice_operand: Some(op_idx),
        predicted: None,
    })
}

/// Rectangular candidates: power-of-two block sizes per loop dimension
/// with working sets near the cache capacity (the classical search space).
pub fn rect_candidates(kernel: &Kernel, spec: &CacheSpec) -> Vec<TilingPlan> {
    let n = kernel.n_free();
    let elem = kernel.operand(0).table.elem();
    let cache_elems = (spec.capacity / elem) as i64;
    let sizes: Vec<i64> = [4i64, 8, 16, 32, 64]
        .iter()
        .copied()
        .filter(|&s| s <= *kernel.extents().iter().max().unwrap())
        .collect();
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    let extents = kernel.extents().to_vec();
    let mut push = |tile: Vec<i64>| {
        // score the microkernel-snapped variant alongside the raw tile
        // (snapped first, so ties in the model prefer full register blocks)
        for t in [snap_to_microkernel(&tile, &extents), tile] {
            if !seen.insert(t.clone()) {
                continue;
            }
            // rough working-set guard: Σ pairwise faces ≤ 4× cache
            let ws: i64 = t[0] * t.get(2).copied().unwrap_or(1)
                + t.get(2).copied().unwrap_or(1) * t.get(1).copied().unwrap_or(1)
                + t[0] * t.get(1).copied().unwrap_or(1);
            if ws > 4 * cache_elems {
                continue;
            }
            out.push(TilingPlan {
                name: format!(
                    "rect {}",
                    t.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("x")
                ),
                schedule: TiledSchedule::new(TileBasis::rect(&t)),
                lattice_operand: None,
                predicted: None,
            });
        }
    };
    // uniform cubes (the classical default)
    for &s in &sizes {
        push(kernel.extents().iter().map(|&e| s.min(e)).collect());
    }
    // anisotropic candidates for 3-D nests: long unit-stride first dim
    // (vector-friendly), small others (set-pressure-friendly)
    if n == 3 {
        for &si in &[32i64, 64] {
            for &sj in &[8i64, 16] {
                for &sk in &[8i64, 16] {
                    let e = kernel.extents();
                    push(vec![si.min(e[0]), sj.min(e[1]), sk.min(e[2])]);
                }
            }
        }
    }
    out
}

/// Model-driven search: score candidates with the sampled Eq.(4) model and
/// return them sorted best-first (fewest predicted misses).
pub fn model_driven_search(
    kernel: &Kernel,
    spec: &CacheSpec,
    mut candidates: Vec<TilingPlan>,
    sample_classes: usize,
) -> Vec<TilingPlan> {
    let model = MissModel::new(kernel, spec);
    let n_classes = model.analysis().n_classes;
    let step = (n_classes as usize / sample_classes.max(1)).max(1);
    let classes: Vec<i64> = (0..n_classes).step_by(step).collect();
    for plan in candidates.iter_mut() {
        let counts = model.sampled(&plan.schedule, &classes);
        plan.predicted = Some(counts);
    }
    candidates.sort_by_key(|p| p.predicted.as_ref().map(|c| c.misses).unwrap_or(u64::MAX));
    candidates
}

/// The paper's full decision procedure ("hybrid approach"): `K−1` lattice
/// plans for each latticeable operand + rectangular candidates, scored by
/// the sampled model; best first.
pub fn select(kernel: &Kernel, spec: &CacheSpec, sample_classes: usize) -> Vec<TilingPlan> {
    let mut cands = rect_candidates(kernel, spec);
    for op_idx in 0..kernel.operands().len() {
        for kappa in [spec.ways as i128 - 2, spec.ways as i128 - 1, spec.ways as i128] {
            if kappa < 1 {
                continue;
            }
            if let Some(p) = plan_with_kappa(kernel, spec, op_idx, kappa) {
                cands.push(p);
            }
        }
    }
    model_driven_search(kernel, spec, cands, sample_classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::ops;

    fn toy_spec() -> CacheSpec {
        // P = 32 elements, K = 4 ways (element-granular lines)
        CacheSpec::new(32 * 4 * 8, 8, 4, 1)
    }

    #[test]
    fn factorizations_complete() {
        let f = factorizations(12, 2);
        assert!(f.contains(&vec![3, 4]));
        assert!(f.contains(&vec![12, 1]));
        assert!(f.contains(&vec![1, 12]));
        for v in &f {
            assert_eq!(v.iter().product::<i128>(), 12);
        }
    }

    #[test]
    fn scaled_tile_has_kappa_points() {
        // the defining property, checked by explicit counting (tests only)
        let l = Lattice::from_congruence(&[1, 24], 32);
        for kappa in [1i128, 3, 7, 8] {
            let t = scaled_lattice_tile(&l, kappa, &[64, 64]);
            assert_eq!(t.volume(), l.det_abs() * kappa);
            // count lattice points in the prototile by scanning it
            let mut count = 0;
            t.scan_tile(&[0, 0], &[1000, 1000], |x| {
                let x128: Vec<i128> = x.iter().map(|&v| v as i128).collect();
                if l.contains(&x128) {
                    count += 1;
                }
            });
            assert_eq!(count, kappa, "kappa={kappa}");
        }
    }

    #[test]
    fn embed_matmul_b_tile() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        let op_tile = TileBasis::rect(&[4, 4]); // on (i, kk)
        let loop_tile = embed_operand_tile(&k, 1, &op_tile, &[0, 8, 0]).unwrap();
        assert_eq!(loop_tile.dim(), 3);
        // volume = 4*4*8
        assert_eq!(loop_tile.volume(), 128);
        // footpoint of (i=5, j=0, kk=0) moves in the i-tile direction
        assert_eq!(loop_tile.footpoint(&[5, 0, 0]), vec![1, 0, 0]);
    }

    #[test]
    fn k_minus_one_plan_exists_for_matmul() {
        let k = ops::matmul(32, 32, 32, 8, 0);
        let plan = k_minus_one_plan(&k, &toy_spec(), 1).expect("plan");
        assert_eq!(plan.lattice_operand, Some(1));
        // schedule covers the domain
        use crate::domain::order::Scanner;
        let mut n = 0usize;
        plan.schedule
            .scan_points(k.extents(), &mut |_: &[i64]| n += 1);
        assert_eq!(n, 32 * 32 * 32);
    }

    #[test]
    fn select_ranks_plans_and_beats_naive() {
        use crate::conflict::MissModel;
        use crate::domain::IterOrder;
        let k = ops::matmul(24, 24, 24, 8, 0);
        let spec = toy_spec();
        let ranked = select(&k, &spec, 8);
        assert!(!ranked.is_empty());
        let best = &ranked[0];
        let model = MissModel::new(&k, &spec);
        let naive = model.exact(&IterOrder::lex(3)).misses;
        let tiled = model.exact(&best.schedule).misses;
        assert!(
            tiled < naive,
            "best plan {} predicted {tiled} ≥ naive {naive}",
            best.name
        );
    }

    #[test]
    fn snap_rounds_inner_dims_to_microkernel_multiples() {
        use crate::codegen::microkernel::{MR, NR};
        let ext = [100i64, 100, 100];
        let t = snap_to_microkernel(&[13, 13, 13], &ext);
        assert_eq!(t[0] % MR as i64, 0);
        assert_eq!(t[1] % NR as i64, 0);
        assert_eq!(t[2], 13, "k dim untouched");
        // never snapped to zero, never past the extent
        let t = snap_to_microkernel(&[3, 2, 5], &ext);
        assert_eq!(t, vec![MR as i64, NR as i64, 5]);
        let t = snap_to_microkernel(&[13, 13], &[5, 2]);
        assert_eq!(t, vec![5, 2], "tiny extents clamp instead of snapping");
    }

    #[test]
    fn rect_candidates_include_snapped_variants() {
        use crate::codegen::microkernel::{MR, NR};
        let k = ops::matmul(100, 100, 100, 8, 0);
        let cands = rect_candidates(&k, &CacheSpec::HASWELL_L1D);
        assert!(cands.iter().any(|p| {
            let b = p.schedule.basis().basis();
            b[(0, 0)] % MR as i128 == 0 && b[(1, 1)] % NR as i128 == 0
        }));
        // no duplicate tile shapes
        let names: Vec<&str> = cands.iter().map(|p| p.name.as_str()).collect();
        let set: std::collections::HashSet<&&str> = names.iter().collect();
        assert_eq!(set.len(), names.len());
    }

    #[test]
    fn heuristic_level_plan_is_aligned_and_bounded() {
        let lp = LevelPlan::heuristic(
            (32, 32, 32),
            (512, 512, 512),
            8,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        );
        assert_eq!(lp.mc % MR, 0);
        assert_eq!(lp.nc % NR, 0);
        assert!(lp.kc >= 1 && lp.kc <= 512);
        // packed B block fits half of L2
        assert!(lp.mc * lp.kc * 8 <= CacheSpec::HASWELL_L2.capacity / 2 + MR * lp.kc * 8);
        // packed C block fits half the L3 slice
        assert!(lp.kc * lp.nc * 8 <= CacheSpec::HASWELL_L3_SLICE.capacity / 2 + NR * lp.kc * 8);
        // the super-band level is mc/nc-aligned and its packed row slice
        // fits a quarter of the L3 slice
        assert_eq!(lp.m3 % lp.mc, 0);
        assert_eq!(lp.n3 % lp.nc, 0);
        let quarter_l3 = CacheSpec::HASWELL_L3_SLICE.capacity / 4;
        assert!(lp.m3 * lp.kc * 8 <= quarter_l3 + lp.mc * lp.kc * 8);
        // tiny problems degenerate to a single macro block
        let small =
            LevelPlan::heuristic((8, 8, 8), (24, 24, 24), 8, &CacheSpec::HASWELL_L2, None);
        assert!(small.mc >= 24 && small.nc >= 24 && small.kc == 24);
        // …and, with no L3 modelled, to a single super-band
        assert!(small.m3 >= 24 && small.n3 >= 24);
    }

    #[test]
    fn heuristic_degenerate_dot_form_blocks_exactly() {
        // convolution / scalar product pass their GEMM form's (1, 1, k):
        // the row/column blocks must degenerate to 1, not pad to MR/NR
        let lp = LevelPlan::heuristic(
            (1, 1, 64),
            (1, 1, 4096),
            8,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        );
        assert_eq!(lp.mc, 1, "{lp:?}");
        assert_eq!(lp.nc, 1, "{lp:?}");
        assert_eq!((lp.m3, lp.n3), (1, 1), "{lp:?}");
        assert!(lp.kc >= 1 && lp.kc <= 4096);
    }

    #[test]
    fn level_plan_is_kernel_aware() {
        use crate::codegen::runplan::GemmForm;
        // convolution: the selector's winning 1-D tile is pure reduction
        // depth — mc/nc must come out 1 (its form has m = n = 1), kc > 1
        let conv = ops::convolution(4096, 8, 0);
        let lp = level_plan(
            &conv,
            (1, 1, 4096),
            (1, 1, 64),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            8,
        );
        assert_eq!((lp.mc, lp.nc), (1, 1), "conv plan not form-aware: {lp:?}");
        assert!(lp.kc > 1, "conv kc must carry the reduction depth: {lp:?}");
        assert_eq!((lp.m3, lp.n3), (1, 1), "conv super-band degenerate: {lp:?}");
        // kronecker: reduction-free outer product — kc must be exactly 1
        // and the row block must track the form's swapped row group
        let kron = ops::kronecker(16, 16, 24, 24, 8, 0);
        let gf = GemmForm::of(&kron).unwrap();
        let lp = level_plan(
            &kron,
            (gf.m, gf.n, gf.k),
            (24, 24, 1),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            8,
        );
        assert_eq!(lp.kc, 1, "kronecker has no reduction to deepen: {lp:?}");
        assert!(lp.mc <= 576 && lp.mc >= 1);
        assert_eq!(lp.m3 % lp.mc, 0);
    }

    #[test]
    fn heuristic_f32_blocks_hold_twice_the_elements() {
        // same shape, half the element size → the L2-resident block
        // carries ~2× the elements (equal bytes), not the same count
        let lp64 = LevelPlan::heuristic(
            (32, 32, 32),
            (2048, 2048, 2048),
            8,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        );
        let lp32 = LevelPlan::heuristic(
            (32, 32, 32),
            (2048, 2048, 2048),
            4,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        );
        assert!(
            lp32.mc * lp32.kc > lp64.mc * lp64.kc,
            "f32 {lp32:?} not wider than f64 {lp64:?}"
        );
        // both still fit half their level in *bytes*
        assert!(lp32.mc * lp32.kc * 4 <= CacheSpec::HASWELL_L2.capacity / 2 + MR * lp32.kc * 4);
    }

    #[test]
    fn model_level_plan_targets_l2() {
        let k = ops::matmul(64, 64, 64, 8, 0);
        let lp = level_plan(
            &k,
            (512, 512, 512),
            (32, 32, 32),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            8,
        );
        assert_eq!(lp.mc % MR, 0);
        assert_eq!(lp.nc % NR, 0);
        assert!(lp.kc >= 1 && lp.kc <= 512);
        assert!(lp.mc >= MR && lp.nc >= NR);
        // the grown block must use a decent fraction of L2 without
        // overflowing half of it (+ one MR row of slack from growth)
        let half_l2_elems = CacheSpec::HASWELL_L2.capacity / 16;
        assert!(lp.mc * lp.kc <= half_l2_elems + MR * lp.kc);
        assert!(lp.mc * lp.kc >= half_l2_elems / 4, "block far too small");
    }

    #[test]
    fn f32_plan_selects_wider_footprint_than_f64() {
        // the dtype must reach the selector: the same 512³ GEMM shape,
        // modelled once with 8-byte and once with 4-byte elements, must
        // yield a strictly larger f32 macro footprint (2× the elements
        // fit each level)
        let k64 = ops::matmul(64, 64, 64, 8, 0);
        let k32 = ops::matmul(64, 64, 64, 4, 0);
        let args = ((512usize, 512usize, 512usize), (32usize, 32usize, 32usize));
        let lp64 = level_plan(
            &k64,
            args.0,
            args.1,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            8,
        );
        let lp32 = level_plan(
            &k32,
            args.0,
            args.1,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            8,
        );
        assert!(
            lp32.mc * lp32.kc > lp64.mc * lp64.kc,
            "f32 plan {lp32:?} not wider than f64 plan {lp64:?}"
        );
        // in bytes both target half of L2 (+ one MR-row of growth slack)
        assert!(lp32.mc * lp32.kc * 4 <= CacheSpec::HASWELL_L2.capacity / 2 + MR * lp32.kc * 4);
        assert!(lp64.mc * lp64.kc * 8 <= CacheSpec::HASWELL_L2.capacity / 2 + MR * lp64.kc * 8);
    }

    #[test]
    fn kronecker_output_cannot_embed() {
        let k = ops::kronecker(2, 2, 3, 3, 8, 0);
        // output access A[3i+k, 3j+l] is not a pure selection
        let t = TileBasis::rect(&[2, 2]);
        assert!(embed_operand_tile(&k, 0, &t, &[1, 1, 1, 1]).is_none());
        // but B is
        assert!(embed_operand_tile(&k, 1, &t, &[1, 1, 2, 2]).is_some());
    }
}
