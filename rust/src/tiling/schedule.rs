//! Tiled traversal schedules: the covering
//! `D ⊆ P_D(H) + H^{-1} T_D(H)` of §3.2 turned into an iteration order.
//!
//! A [`TiledSchedule`] visits footpoints of `T_D(H)` in an outer order and
//! the integer points of each tile in an inner lexicographic order — the
//! loop structure the paper generates with CLooG, here executed directly.

use crate::domain::order::{IterOrder, Scanner};

use super::tile::TileBasis;

/// A tiled iteration schedule over the box `[0, extents_i)`.
#[derive(Clone, Debug)]
pub struct TiledSchedule {
    basis: TileBasis,
    /// Order of the footpoint loop (dimension = tile dim).
    foot_order: IterOrder,
}

impl TiledSchedule {
    pub fn new(basis: TileBasis) -> TiledSchedule {
        let d = basis.dim();
        TiledSchedule {
            basis,
            foot_order: IterOrder::lex(d),
        }
    }

    pub fn with_foot_order(mut self, order: IterOrder) -> TiledSchedule {
        assert_eq!(order.n(), self.basis.dim());
        self.foot_order = order;
        self
    }

    pub fn basis(&self) -> &TileBasis {
        &self.basis
    }

    /// Visit every footpoint whose tile intersects the box, in the foot
    /// order, calling `f(foot)`.
    pub fn scan_feet<F: FnMut(&[i128])>(&self, extents: &[i64], mut f: F) {
        let (lo, hi) = self.basis.foot_bounds(extents);
        let d = lo.len();
        let foot_extents: Vec<i64> = (0..d).map(|j| (hi[j] - lo[j] + 1) as i64).collect();
        let mut foot = vec![0i128; d];
        self.foot_order.scan(&foot_extents, |rel| {
            for j in 0..d {
                foot[j] = lo[j] + rel[j] as i128;
            }
            f(&foot);
        });
    }

    /// Count of footpoints (incl. empty boundary tiles).
    pub fn n_feet(&self, extents: &[i64]) -> usize {
        let (lo, hi) = self.basis.foot_bounds(extents);
        (0..lo.len())
            .map(|j| (hi[j] - lo[j] + 1) as usize)
            .product()
    }
}

impl Scanner for TiledSchedule {
    fn scan_points(&self, extents: &[i64], f: &mut dyn FnMut(&[i64])) {
        self.scan_feet(extents, |foot| {
            self.basis.scan_tile(foot, extents, |x| f(x));
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(s: &TiledSchedule, extents: &[i64]) -> Vec<Vec<i64>> {
        let mut pts = Vec::new();
        s.scan_points(extents, &mut |x: &[i64]| pts.push(x.to_vec()));
        pts
    }

    #[test]
    fn tiled_schedule_visits_all_points_once() {
        let s = TiledSchedule::new(TileBasis::rect(&[4, 3]));
        let pts = collect(&s, &[10, 7]);
        assert_eq!(pts.len(), 70);
        let set: std::collections::HashSet<_> = pts.iter().cloned().collect();
        assert_eq!(set.len(), 70);
    }

    #[test]
    fn skewed_schedule_visits_all_points_once() {
        use crate::lattice::IMat;
        let basis = TileBasis::from_cols(IMat::from_rows(&[&[3, 1], &[1, 4]]));
        let s = TiledSchedule::new(basis);
        let pts = collect(&s, &[11, 13]);
        assert_eq!(pts.len(), 11 * 13);
        let set: std::collections::HashSet<_> = pts.iter().cloned().collect();
        assert_eq!(set.len(), 11 * 13);
    }

    #[test]
    fn rect_tiled_matches_blocked_loop() {
        // 1-D sanity: tiles of 4 over [0,10) = blocks 0-3, 4-7, 8-9
        let s = TiledSchedule::new(TileBasis::rect(&[4]));
        let pts = collect(&s, &[10]);
        let flat: Vec<i64> = pts.into_iter().map(|p| p[0]).collect();
        assert_eq!(flat, vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn foot_order_changes_visit_sequence() {
        let a = TiledSchedule::new(TileBasis::rect(&[2, 2]));
        let b = TiledSchedule::new(TileBasis::rect(&[2, 2]))
            .with_foot_order(IterOrder::permuted(&[1, 0]));
        let pa = collect(&a, &[4, 4]);
        let pb = collect(&b, &[4, 4]);
        assert_ne!(pa, pb);
        let sa: std::collections::HashSet<_> = pa.into_iter().collect();
        let sb: std::collections::HashSet<_> = pb.into_iter().collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn tiling_reduces_model_misses_on_matmul() {
        // The point of the whole paper, in miniature: a tiled schedule
        // must beat the naive ijk order on a conflict-heavy matmul.
        use crate::cache::CacheSpec;
        use crate::conflict::MissModel;
        use crate::domain::ops;
        let n = 16i64;
        let k = ops::matmul(n, n, n, 8, 0);
        let spec = CacheSpec::new(16 * 2 * 8, 8, 2, 1); // P=16, K=2
        let model = MissModel::new(&k, &spec);
        let naive = model.exact(&IterOrder::lex(3)).misses;
        let blocked = [2i64, 4, 8]
            .iter()
            .map(|&s| {
                let t = TiledSchedule::new(TileBasis::rect(&[s, s, s]));
                model.exact(&t).misses
            })
            .min()
            .unwrap();
        assert!(
            blocked < naive,
            "best tiled {blocked} should beat naive {naive}"
        );
    }
}
