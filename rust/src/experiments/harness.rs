//! Tiny benchmark harness (criterion is unavailable offline): warmup +
//! repeated timing with median/min reporting, plus table printing.

use std::time::{Duration, Instant};

/// Time `f` with `reps` measured repetitions (after one warmup); returns
/// (median, min).
pub fn time_reps<F: FnMut()>(reps: usize, mut f: F) -> (Duration, Duration) {
    f(); // warmup
    let mut times: Vec<Duration> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    times.sort();
    (times[times.len() / 2], times[0])
}

/// Format a duration in adaptive units.
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// A plain-text aligned table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Geometric mean of positive values.
pub fn geomean(vals: &[f64]) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    (vals.iter().map(|v| v.ln()).sum::<f64>() / vals.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("a"));
        assert_eq!(r.lines().count(), 4);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn time_reps_returns_ordered() {
        let (med, min) = time_reps(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(min <= med);
    }
}
