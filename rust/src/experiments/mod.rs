//! Experiment reproductions — one module per paper table/figure
//! (DESIGN.md §2 per-experiment index). Shared by the `benches/` harness
//! binaries and the `latticetile bench` CLI.

pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod harness;
pub mod model_cost;
pub mod multilevel;
pub mod policy;
pub mod strategy_race;
