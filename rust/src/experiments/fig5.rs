//! Figure 5 / E7: spatial-reuse deficit of lattice tiles.
//!
//! Lattice tiles maximize addressable volume per cache set, but their
//! skewed boundaries cut cachelines: a line loaded for one tile may have
//! elements belonging to the neighbor tile. We quantify this as
//! **cacheline utilization**: tile points / (lines touched × elements per
//! line), computed exactly per tile for the operand the tile shapes.

use std::collections::HashSet;

use crate::cache::CacheSpec;
use crate::index::Table;
use crate::tiling::TileBasis;

/// Utilization statistics over the interior tiles of a 2-D operand tiling.
#[derive(Clone, Debug)]
pub struct Utilization {
    pub tiles_measured: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
}

/// Measure cacheline utilization of `tile` (2-D, on the operand's index
/// space) over `table`, sampling all whole tiles with footpoints in
/// `[0, feet)²`.
pub fn line_utilization(
    table: &Table,
    tile: &TileBasis,
    spec: &CacheSpec,
    feet: i128,
) -> Utilization {
    assert_eq!(tile.dim(), 2);
    let dims = table.dims();
    let extents = [dims[0], dims[1]];
    let mut utils = Vec::new();
    for fa in 0..feet {
        for fb in 0..feet {
            let foot = [fa, fb];
            let mut points = 0usize;
            let mut lines: HashSet<usize> = HashSet::new();
            let mut clipped = false;
            tile.scan_tile(&foot, &extents, |x| {
                points += 1;
                lines.insert(spec.line_of_addr(table.addr(x)));
            });
            if points as i128 != tile.volume() {
                clipped = true; // boundary tile — skip for the interior stat
            }
            if !clipped && points > 0 {
                let capacity = lines.len() * spec.elems_per_line(table.elem());
                utils.push(points as f64 / capacity as f64);
            }
        }
    }
    let n = utils.len();
    let mean = utils.iter().sum::<f64>() / n.max(1) as f64;
    Utilization {
        tiles_measured: n,
        mean,
        min: utils.iter().copied().fold(f64::INFINITY, f64::min),
        max: utils.iter().copied().fold(0.0, f64::max),
    }
}

/// The Figure 5 comparison: a rectangular tile and a skewed lattice tile
/// of equal volume over the same operand; returns (rect, lattice).
pub fn run(n: i64) -> (Utilization, Utilization) {
    use crate::index::Layout;
    let spec = CacheSpec::HASWELL_L1D;
    let table = Table::new("B", &[n, n], Layout::ColumnMajor, 8, 0);
    // rect 16×8 (=128 pts, row-aligned) vs a skewed tile of equal volume
    let rect = TileBasis::rect(&[16, 8]);
    let skew = TileBasis::from_cols(crate::lattice::IMat::from_rows(&[
        &[16, 8],
        &[-8, 4],
    ])); // det = 64 + 64 = 128
    assert_eq!(rect.volume(), skew.volume());
    (
        line_utilization(&table, &rect, &spec, 4),
        line_utilization(&table, &skew, &spec, 4),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_tiles_have_higher_spatial_utilization() {
        // The paper's Figure 5 claim, quantified: equal-volume skewed
        // tiles waste part of each cacheline.
        let (rect, lattice) = run(256);
        assert!(rect.tiles_measured > 0 && lattice.tiles_measured > 0);
        assert!(
            rect.mean > lattice.mean,
            "rect {:.3} should beat lattice {:.3}",
            rect.mean,
            lattice.mean
        );
        // rows of the rect tile are 16 long = 2 whole lines → utilization 1
        assert!(rect.mean > 0.99);
    }

    #[test]
    fn utilization_bounded() {
        let (rect, lattice) = run(128);
        for u in [rect, lattice] {
            assert!(u.min > 0.0 && u.max <= 1.0 + 1e-12);
        }
    }
}
