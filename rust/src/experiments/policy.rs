//! §1.1.4 / E10: eviction-policy study — LRU vs tree-PLRU.
//!
//! The paper implements model variants for both policies and compares
//! which matches hardware. We measure simulated miss counts for both
//! policies over the same schedules, quantifying how much policy choice
//! moves the numbers (and therefore how much model error a wrong policy
//! assumption would introduce).

use crate::baseline::CompilerAnalog;
use crate::cache::{CacheSim, CacheSpec, Policy};
use crate::codegen::run_trace_only;
use crate::domain::ops;
use crate::experiments::fig4::lattice_plan_for;

#[derive(Clone, Debug)]
pub struct PolicyRow {
    pub n: i64,
    pub strategy: String,
    pub lru: u64,
    pub plru: u64,
    /// |plru − lru| / lru
    pub rel_delta: f64,
}

pub fn run(sizes: &[i64]) -> Vec<PolicyRow> {
    let mut rows = Vec::new();
    for &n in sizes {
        let kernel = ops::matmul(n, n, n, 8, 0);
        let mut strategies: Vec<(String, Box<dyn Fn(&mut CacheSim)>)> = Vec::new();
        for analog in [CompilerAnalog::GccO0, CompilerAnalog::GccO2, CompilerAnalog::GccO3] {
            let k = kernel.clone();
            strategies.push((
                analog.name().to_string(),
                Box::new(move |sim: &mut CacheSim| {
                    let s = analog.schedule(&k);
                    run_trace_only(&k, s.as_scanner(), sim);
                }),
            ));
        }
        {
            let k = kernel.clone();
            let plan = lattice_plan_for(n, &CacheSpec::HASWELL_L1D);
            strategies.push((
                "lattice(ours)".to_string(),
                Box::new(move |sim: &mut CacheSim| {
                    run_trace_only(&k, &plan, sim);
                }),
            ));
        }
        for (name, runner) in strategies {
            let mut lru =
                CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru).without_classification();
            let mut plru =
                CacheSim::new(CacheSpec::HASWELL_L1D, Policy::PLru).without_classification();
            runner(&mut lru);
            runner(&mut plru);
            let (l, p) = (lru.stats().misses(), plru.stats().misses());
            rows.push(PolicyRow {
                n,
                strategy: name,
                lru: l,
                plru: p,
                rel_delta: (p as f64 - l as f64).abs() / l.max(1) as f64,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policies_close_but_not_identical() {
        let rows = run(&[64]);
        assert_eq!(rows.len(), 4);
        // policy is a second-order effect (the paper calls associativity
        // the first-order one): deltas well under 50%...
        for r in &rows {
            assert!(r.rel_delta < 0.5, "{}: Δ={:.2}", r.strategy, r.rel_delta);
        }
        // ...but at least one schedule must show a nonzero delta
        assert!(rows.iter().any(|r| r.rel_delta > 0.0));
    }
}
