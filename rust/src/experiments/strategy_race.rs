//! Strategy race — model-driven lattice tiling vs its rivals, measured.
//!
//! The pluggable [`TilingStrategy`](crate::tiling::TilingStrategy) layer
//! claims the associativity-lattice model earns its analysis cost; this
//! experiment checks that claim empirically. Every registered strategy
//! (lattice, cache-oblivious, latency-curve) proposes a macro-block
//! [`LevelPlan`] for each Table-1 kernel at both dtypes, each plan is
//! raced through the packed engine, and the table reports per-strategy
//! throughput, the auto-selected winner (the [`pick_winner`] rule the
//! serve path's startup race applies — ties keep the lattice incumbent),
//! the parameter-free flat fallback as the degradation baseline, and the
//! model's predicted L1 misses for the lattice plan. The summary rows
//! give the model-vs-empirical win rate: how often the lattice model's
//! plan is also the measured fastest, and how many cells it missed.
//!
//! A `hot_paths` row races the native serve path's transpose-lowered
//! GEMM at f32, tying this report to the serving benchmarks. The JSON
//! (`BENCH_strategy_race.json`) feeds `python/check_bench.py`; the
//! committed baseline holds machine-independent **ratio floors** — auto
//! must never fall below the flat fallback, and the lattice plan must
//! not regress against a rival it previously beat.

use crate::cache::CacheSpec;
use crate::codegen::{measure_plan_rate, pick_winner, race_strategy_rates, DType, MicroShape};
use crate::domain::{ops, Kernel};
use crate::tiling::{self, LevelPlan, StrategyKind};

/// One raced (kernel, dtype) cell.
pub struct RaceCell {
    /// Kernel label (`matmul`, `kronecker`, `convolution`,
    /// `scalar_product`, or the serve-path tie-in `hot_paths`).
    pub kernel: String,
    pub dtype: DType,
    /// Measured GFLOP/s per strategy, lattice first (the race order).
    pub rates: Vec<(StrategyKind, f64)>,
    /// The parameter-free flat fallback plan's GFLOP/s — the degraded
    /// serve baseline every strategy must beat to be worth racing.
    pub flat: f64,
    /// The auto-dispatched winner under [`pick_winner`]'s
    /// tie-keeps-default rule (lattice is the incumbent).
    pub winner: StrategyKind,
    /// The winner's measured rate — what `auto` dispatch serves.
    pub auto: f64,
    /// The lattice model's predicted L1 misses for its top-ranked plan
    /// (the §4.0.4 selector's cost estimate), when the model ranks one.
    pub predicted_misses: Option<u64>,
}

impl RaceCell {
    /// Rate of one strategy in this cell (0.0 if it did not race).
    pub fn rate_of(&self, kind: StrategyKind) -> f64 {
        self.rates
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, r)| *r)
            .unwrap_or(0.0)
    }

    /// Did the lattice model's plan also win the empirical race?
    pub fn model_hit(&self) -> bool {
        self.winner == StrategyKind::Lattice
    }
}

/// The four Table-1 kernels at `elem` bytes, quick or full sizes.
fn table1_kernels(elem: usize, quick: bool) -> Vec<(&'static str, Kernel)> {
    let (mm, mk, mn) = if quick { (48, 32, 40) } else { (96, 64, 80) };
    let (kb, kc) = if quick { (6, 8) } else { (10, 12) };
    let nvec = if quick { 4_096 } else { 65_536 };
    vec![
        ("matmul", ops::matmul(mm, mk, mn, elem, 0)),
        ("kronecker", ops::kronecker(kb, kb, kc, kc, elem, 0)),
        ("convolution", ops::convolution(nvec, elem, 0)),
        ("scalar_product", ops::scalar_product(nvec, elem, 0)),
    ]
}

/// The native serve path's transpose-lowered GEMM (serve columns are
/// GEMM rows) — the `hot_paths` tie-in shape, f32 like the serve path.
fn hot_paths_kernel(quick: bool) -> Kernel {
    let n = if quick { 64 } else { 128 };
    ops::matmul(n, n, n, DType::F32.elem(), 0)
}

fn race_cell<T: crate::codegen::Scalar>(
    label: &str,
    kernel: &Kernel,
    micro: MicroShape,
    reps: usize,
) -> RaceCell {
    let rates = race_strategy_rates::<T>(kernel, micro, 8, reps);
    let winner = pick_winner(&rates);
    let flat_lp = LevelPlan::flat((8, 8, 8), 64, 64, 48);
    let flat = measure_plan_rate::<T>(kernel, &flat_lp, micro, reps);
    let auto = rates
        .iter()
        .find(|(k, _)| *k == winner)
        .map(|(_, r)| *r)
        .unwrap_or(0.0);
    let predicted_misses = tiling::select(kernel, &CacheSpec::HASWELL_L1D, 8)
        .first()
        .and_then(|p| p.predicted.as_ref().map(|c| c.misses));
    RaceCell {
        kernel: label.to_string(),
        dtype: T::DTYPE,
        rates,
        flat,
        winner,
        auto,
        predicted_misses,
    }
}

/// Race every registered strategy over the Table-1 kernels at both
/// dtypes plus the `hot_paths` serve shape at f32. `quick` shrinks the
/// raced sizes for CI smoke runs.
pub fn run(quick: bool) -> Vec<RaceCell> {
    let reps = if quick { 2 } else { 5 };
    let micro = MicroShape::Mr8Nr4;
    let mut cells = Vec::new();
    for (label, kernel) in table1_kernels(DType::F64.elem(), quick) {
        cells.push(race_cell::<f64>(label, &kernel, micro, reps));
    }
    for (label, kernel) in table1_kernels(DType::F32.elem(), quick) {
        cells.push(race_cell::<f32>(label, &kernel, micro, reps));
    }
    cells.push(race_cell::<f32>(
        "hot_paths",
        &hot_paths_kernel(quick),
        micro,
        reps,
    ));
    cells
}

/// Model-vs-empirical summary: `(lattice wins, cells, model misses)` —
/// a "miss" is a cell where a rival strategy measured faster than the
/// lattice model's plan by more than [`pick_winner`]'s upgrade margin.
pub fn win_summary(cells: &[RaceCell]) -> (usize, usize, usize) {
    let wins = cells.iter().filter(|c| c.model_hit()).count();
    (wins, cells.len(), cells.len() - wins)
}

/// Render the race as the committed-JSON body (label → GFLOP/s rows the
/// baseline ratio floors reference). Keys:
/// `strategy race <kernel> <dtype> <strategy> GFLOP/s`, plus `flat` and
/// `auto` pseudo-strategies and a `model_misses` count row.
pub fn to_json(cells: &[RaceCell]) -> String {
    let mut body = Vec::new();
    for c in cells {
        let pre = format!("strategy race {} {}", c.kernel, c.dtype.name());
        for (kind, rate) in &c.rates {
            body.push(format!("  \"{pre} {} GFLOP/s\": {rate:.3}", kind.name()));
        }
        body.push(format!("  \"{pre} flat GFLOP/s\": {:.3}", c.flat));
        body.push(format!("  \"{pre} auto GFLOP/s\": {:.3}", c.auto));
    }
    let (wins, total, misses) = win_summary(cells);
    body.push(format!("  \"strategy race lattice wins\": {wins}"));
    body.push(format!("  \"strategy race cells\": {total}"));
    body.push(format!("  \"strategy race model_misses\": {misses}"));
    format!("{{\n{}\n}}\n", body.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_race_covers_every_table1_kernel_at_both_dtypes() {
        let cells = run(true);
        // 4 kernels × 2 dtypes + the hot_paths serve row
        assert_eq!(cells.len(), 9);
        for name in ["matmul", "kronecker", "convolution", "scalar_product"] {
            for dt in [DType::F64, DType::F32] {
                assert!(
                    cells.iter().any(|c| c.kernel == name && c.dtype == dt),
                    "missing cell {name}/{}",
                    dt.name()
                );
            }
        }
        assert!(cells.iter().any(|c| c.kernel == "hot_paths"));
        for c in &cells {
            assert_eq!(
                c.rates.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
                StrategyKind::RACED.to_vec(),
                "{}: every registered strategy must race, lattice first",
                c.kernel
            );
            assert!(
                c.rates.iter().all(|&(_, r)| r > 0.0),
                "{}: GEMM-form cells must measure non-zero rates",
                c.kernel
            );
            assert!(c.flat > 0.0 && c.auto > 0.0, "{}", c.kernel);
            assert!(
                c.auto >= c.rate_of(c.winner) * 0.999,
                "{}: auto serves the winner's measured rate",
                c.kernel
            );
        }
    }

    #[test]
    fn json_rows_carry_the_ratio_floor_operands() {
        let cells = vec![RaceCell {
            kernel: "matmul".to_string(),
            dtype: DType::F32,
            rates: vec![
                (StrategyKind::Lattice, 10.0),
                (StrategyKind::Oblivious, 8.0),
                (StrategyKind::Latency, 9.0),
            ],
            flat: 7.0,
            winner: StrategyKind::Lattice,
            auto: 10.0,
            predicted_misses: Some(123),
        }];
        let json = to_json(&cells);
        // exactly the operand labels the committed baseline's ratio
        // floors (auto ≥ flat, lattice vs rivals) divide
        for needle in [
            "\"strategy race matmul f32 lattice GFLOP/s\": 10.000",
            "\"strategy race matmul f32 oblivious GFLOP/s\": 8.000",
            "\"strategy race matmul f32 latency GFLOP/s\": 9.000",
            "\"strategy race matmul f32 flat GFLOP/s\": 7.000",
            "\"strategy race matmul f32 auto GFLOP/s\": 10.000",
            "\"strategy race lattice wins\": 1",
            "\"strategy race model_misses\": 0",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
        assert!(json.ends_with("}\n") && json.starts_with("{\n"));
    }

    #[test]
    fn win_summary_counts_model_hits_and_misses() {
        let mk = |winner| RaceCell {
            kernel: "matmul".to_string(),
            dtype: DType::F64,
            rates: Vec::new(),
            flat: 1.0,
            winner,
            auto: 1.0,
            predicted_misses: None,
        };
        let cells = vec![
            mk(StrategyKind::Lattice),
            mk(StrategyKind::Oblivious),
            mk(StrategyKind::Lattice),
        ];
        assert_eq!(win_summary(&cells), (2, 3, 1));
    }
}
