//! Figure 6 / E8: automatic threading — our tiled parallel execution vs
//! the graphite-analog.
//!
//! The paper's generated OpenMP code scaled to 20 threads while
//! gcc-graphite saturated around 4. The mechanism we reproduce: scaling is
//! bounded by the number of independent outer-tile work units. Graphite's
//! fixed 64³ tiles give only `n/64` parallel column bands; the
//! model-driven plan uses finer `j` bands (the lattice tile constrains
//! `(i,k)`, leaving `j` free to split), so it keeps scaling.

use std::time::Duration;

use crate::codegen::executor::KernelBuffers;
use crate::codegen::run_parallel;
use crate::domain::ops;
use crate::lattice::IMat;
use crate::tiling::{TileBasis, TiledSchedule};

use super::harness::time_reps;

#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub threads: usize,
    pub ours: Duration,
    pub graphite: Duration,
    /// Measured wallclock speedups (≈1 on a single-core host — see
    /// DESIGN.md §3: this testbed has 1 core; the mechanism is captured
    /// by the modeled speedups below).
    pub ours_speedup: f64,
    pub graphite_speedup: f64,
    /// Load-balance speedup bound: total points / max per-thread points
    /// under round-robin band assignment. Exact structural parallelism of
    /// each plan — what a multicore host realizes (up to memory limits).
    pub ours_modeled: f64,
    pub graphite_modeled: f64,
}

/// Our parallel plan: lattice-shaped (i,k) tile + fine j bands (16).
fn ours_schedule(n: i64) -> TiledSchedule {
    // modest skewed (i,k) tile, j decoupled for clean bands
    let basis = TileBasis::from_cols(IMat::from_rows(&[
        &[32, 0, 8],
        &[0, 16, 0],
        &[-8, 0, 16],
    ]));
    let _ = n;
    TiledSchedule::new(basis)
}

/// Graphite-analog: fixed 64³ rectangular tiles → only n/64 j-bands.
fn graphite_schedule(n: i64) -> TiledSchedule {
    let t = 64i64.min(n);
    TiledSchedule::new(TileBasis::rect(&[t, t, t]))
}

/// Points of work per j-band of a schedule.
fn band_loads(n: i64, s: &TiledSchedule) -> Vec<u64> {
    let kernel = ops::matmul(n, n, n, 8, 0);
    let mut loads: std::collections::BTreeMap<i128, u64> = std::collections::BTreeMap::new();
    let basis = s.basis();
    s.scan_feet(kernel.extents(), |foot| {
        let c = basis.tile_point_count(foot, kernel.extents());
        *loads.entry(foot[1]).or_default() += c as u64;
    });
    loads.into_values().collect()
}

/// Load-balance speedup bound for `threads` workers over the given bands
/// (round-robin assignment, matching `run_parallel`).
fn modeled_speedup(bands: &[u64], threads: usize) -> f64 {
    let total: u64 = bands.iter().sum();
    let mut per = vec![0u64; threads];
    // round-robin over bands in order (the work queue hands them out
    // dynamically; for equal bands this matches)
    let mut sorted: Vec<u64> = bands.to_vec();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    for w in sorted {
        let idx = per
            .iter()
            .enumerate()
            .min_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        per[idx] += w;
    }
    total as f64 / *per.iter().max().unwrap() as f64
}

pub fn run(n: i64, threads_list: &[usize], reps: usize) -> Vec<Fig6Row> {
    let kernel = ops::matmul(n, n, n, 8, 0);
    let ours = ours_schedule(n);
    let graphite = graphite_schedule(n);
    let ours_bands = band_loads(n, &ours);
    let graphite_bands = band_loads(n, &graphite);

    let mut base_ours = Duration::ZERO;
    let mut base_graphite = Duration::ZERO;
    let mut rows = Vec::new();
    for &t in threads_list {
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let (w_ours, _) = time_reps(reps, || {
            bufs.reset_output();
            run_parallel(&mut bufs, &kernel, &ours, t, 1);
        });
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let (w_graphite, _) = time_reps(reps, || {
            bufs.reset_output();
            run_parallel(&mut bufs, &kernel, &graphite, t, 1);
        });
        if t == threads_list[0] {
            base_ours = w_ours;
            base_graphite = w_graphite;
        }
        rows.push(Fig6Row {
            threads: t,
            ours: w_ours,
            graphite: w_graphite,
            ours_speedup: base_ours.as_secs_f64() / w_ours.as_secs_f64(),
            graphite_speedup: base_graphite.as_secs_f64() / w_graphite.as_secs_f64(),
            ours_modeled: modeled_speedup(&ours_bands, t),
            graphite_modeled: modeled_speedup(&graphite_bands, t),
        });
    }
    rows
}

/// Structural scaling bound: number of independent j-bands each plan has.
pub fn parallel_grain(n: i64) -> (usize, usize) {
    let kernel = ops::matmul(n, n, n, 8, 0);
    let count_bands = |s: &TiledSchedule| {
        let mut set = std::collections::HashSet::new();
        s.scan_feet(kernel.extents(), |foot| {
            set.insert(foot[1]);
        });
        set.len()
    };
    (count_bands(&ours_schedule(n)), count_bands(&graphite_schedule(n)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::max_abs_diff;

    #[test]
    fn parallel_results_correct_both_plans() {
        let n = 64i64;
        let kernel = ops::matmul(n, n, n, 8, 0);
        for sched in [ours_schedule(n), graphite_schedule(n)] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let want = bufs.reference();
            run_parallel(&mut bufs, &kernel, &sched, 4, 1);
            assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
        }
    }

    #[test]
    fn modeled_speedup_shows_fig6_divergence() {
        // the Figure 6 mechanism as numbers: at 16 threads the
        // graphite-analog is capped by its 4 bands; ours keeps scaling.
        let ours = band_loads(256, &ours_schedule(256));
        let graphite = band_loads(256, &graphite_schedule(256));
        assert!(modeled_speedup(&graphite, 16) <= 4.01);
        assert!(modeled_speedup(&ours, 16) > 10.0);
        // monotone in threads
        assert!(modeled_speedup(&ours, 8) >= modeled_speedup(&ours, 4));
    }

    #[test]
    fn ours_has_finer_parallel_grain() {
        // n=256: graphite gets 4 bands (256/64); ours gets 16 (256/16) —
        // the structural reason Figure 6's curves diverge.
        let (ours, graphite) = parallel_grain(256);
        assert_eq!(graphite, 4);
        assert!(ours >= 16);
    }
}
