//! §4.0.4 / E9: analysis-cost comparison.
//!
//! Full evaluation of Eq. (4) is as expensive as running the code; the
//! paper's remedies are (a) class sampling and (b) the `K−1` closed-form
//! constructor whose cost is dominated by lattice basis reduction and is
//! independent of the problem size. We measure all three.

use std::time::Duration;

use crate::cache::CacheSpec;
use crate::conflict::MissModel;
use crate::domain::{ops, IterOrder};
use crate::tiling;

use super::harness::time_reps;

#[derive(Clone, Debug)]
pub struct ModelCostRow {
    pub n: i64,
    /// Exact Eq.(4) evaluation (stack-distance semantics).
    pub exact: Duration,
    /// Paper-literal Δ-rule evaluation.
    pub exact_paper: Duration,
    /// Sampled evaluation (8 classes).
    pub sampled: Duration,
    /// `K−1` closed-form construction (LLL + embed), no evaluation.
    pub k_minus_one: Duration,
}

pub fn run(sizes: &[i64], reps: usize) -> Vec<ModelCostRow> {
    let spec = CacheSpec::HASWELL_L1D;
    sizes
        .iter()
        .map(|&n| {
            let kernel = ops::matmul(n, n, n, 8, 0);
            let model = MissModel::new(&kernel, &spec);
            let order = IterOrder::lex(3);
            let classes: Vec<i64> = (0..model.analysis().n_classes)
                .step_by((model.analysis().n_classes as usize / 8).max(1))
                .collect();
            let (exact, _) = time_reps(reps, || {
                std::hint::black_box(model.exact(&order));
            });
            let (exact_paper, _) = time_reps(reps, || {
                std::hint::black_box(model.exact_paper(&order));
            });
            let (sampled, _) = time_reps(reps, || {
                std::hint::black_box(model.sampled(&order, &classes));
            });
            let (k_minus_one, _) = time_reps(reps, || {
                std::hint::black_box(tiling::k_minus_one_plan(&kernel, &spec, 1));
            });
            ModelCostRow {
                n,
                exact,
                exact_paper,
                sampled,
                k_minus_one,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_minus_one_cost_is_size_independent() {
        let rows = run(&[16, 32], 1);
        // closed-form constructor should not blow up with n while exact
        // evaluation grows ~n³; allow generous slack for timing noise.
        let grow_exact = rows[1].exact.as_secs_f64() / rows[0].exact.as_secs_f64().max(1e-9);
        let grow_k1 =
            rows[1].k_minus_one.as_secs_f64() / rows[0].k_minus_one.as_secs_f64().max(1e-9);
        assert!(
            grow_exact > 2.0,
            "exact cost should grow with n (got {grow_exact:.1}x)"
        );
        assert!(
            grow_k1 < grow_exact,
            "K−1 constructor should scale better than exact evaluation"
        );
    }
}
