//! Extension (paper §4 future work): multi-level behaviour of the chosen
//! tilings — and of the macro-kernel that now exploits it.
//!
//! The paper tiles for a single level (L1) and defers multi-level tiling.
//! This experiment quantifies both sides: each plan runs through a
//! three-level Haswell hierarchy (L1d 32 KiB/8-way + L2 256 KiB/8-way +
//! a 2 MiB/16-way L3 slice) and reports per-level misses, and the
//! three-level **macro-kernel** (L3 super-bands over L2 macro blocks,
//! [`run_macro`](crate::codegen::run_macro)) is traced at address level
//! — pack reads stream the arena once per macro block, micro-kernel reads
//! hit the packed panels (which get their own simulated addresses past
//! the arena) — so its L2 advantage over the single-level plans is
//! *measured*, not asserted. Since the `RunPlan` refactor the tracer is
//! kernel-agnostic: it walks the same [`RunPlan`] / panel enumeration the
//! real engine executes, for matmul, convolution and Kronecker alike.
//! Rows also carry executed Mops/s so the simulated and real orderings
//! can be compared.
//!
//! [`trace_macro_kernel_pipelined`] additionally models the parallel
//! engine's **pack-ahead pipeline**: stage `k0+kc`'s pack accesses are
//! emitted before stage `k0`'s compute accesses, and the packed panels
//! alternate between **two** stage-set address ranges (the double
//! buffer), so the reordering's cache cost — the second set's cold
//! lines, plus any eviction pressure from the deeper in-flight working
//! set — is measured against the synchronous schedule rather than
//! assumed away.

use std::time::Instant;

use crate::baseline::CompilerAnalog;
use crate::cache::{CacheSpec, Hierarchy, Policy};
use crate::codegen::executor::{max_abs_diff, run_macro, run_schedule, KernelBuffers};
use crate::codegen::runplan::{kernel_views, GemmForm, RowPanel, RunPlan};
use crate::codegen::{MicroShape, PackedCols, PackedRows, MR, NR};
use crate::domain::ops;
use crate::domain::order::Scanner;
use crate::domain::Kernel;
use crate::experiments::fig4::hybrid_plan_for;
use crate::tiling::LevelPlan;

#[derive(Clone, Debug)]
pub struct MultiLevelRow {
    pub n: i64,
    pub strategy: String,
    pub l1_misses: u64,
    pub l2_misses: u64,
    /// Misses of the modelled L3 slice (the level the super-band
    /// schedule is sized against).
    pub l3_misses: u64,
    /// Simple cycle estimate from the hierarchy's latency model.
    pub est_cycles: u64,
    /// Executed throughput of the strategy (lattice points per second,
    /// in millions), measured on real buffers.
    pub mops: f64,
}

/// Per-point address trace of a scanner-driven schedule (operands in
/// order out, in1, in2 per visited point, write-allocate output) — any
/// Table-1 kernel, through the composed operand views.
pub fn trace_pointwise(kernel: &Kernel, scanner: &dyn Scanner, h: &mut Hierarchy) {
    let views = kernel_views(kernel);
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        for v in &views {
            h.access(v.addr(f));
        }
    });
}

/// The macro shape this experiment simulates: quarter-L2 packed row and
/// column blocks, so both stay resident together with the output band
/// during a macro block (`nc` is bounded the same way as `mc`), and a
/// single super-band — the sizes this experiment sweeps stay below the
/// L3 slice, so the flat schedule is the right default; the super-band
/// split is exercised explicitly by
/// [`super_bands_cut_l3_misses_when_flat_bands_thrash`](self).
pub fn macro_plan_for(kernel: &Kernel) -> LevelPlan {
    let gf = GemmForm::of(kernel).expect("GEMM-form kernel");
    let (m, n, k) = (gf.m, gf.n, gf.k);
    let quarter = CacheSpec::HASWELL_L2.capacity / (4 * 8);
    let kc = k.clamp(1, 128);
    let mc = ((quarter / kc).max(MR) / MR * MR).min(m.div_ceil(MR) * MR);
    let nc = ((quarter / kc).max(NR) / NR * NR).min(n.div_ceil(NR) * NR);
    LevelPlan {
        l1_tile: (32.min(m.max(1)), 32.min(n.max(1)), 32.min(k.max(1))),
        mc,
        kc,
        nc,
        m3: m.max(1).div_ceil(mc) * mc,
        n3: n.max(1).div_ceil(nc) * nc,
    }
}

/// Address-level trace of the three-level macro-kernel, mirroring
/// [`run_macro`] over the kernel's whole-domain [`RunPlan`] exactly —
/// including the `m3×n3` L3 super-band nest: each super-band packs its
/// own row slice per reduction step (into the *same* reused buffer
/// addresses, like the real thread-local `Vec`s), pack reads/writes
/// touch the arena and the packed buffers (placed line-aligned past the
/// arena), the micro-kernel reads only packed panels, and each output
/// element is touched once per register block per reduction slice.
/// Works for any GEMM-form kernel (the trace models the default f64 8×4
/// register tile; degenerate `m = n = 1` kernels are traced through the
/// packed formulation even though the real engine now short-circuits
/// them into the dot microkernel — the trace is an upper bound there).
pub fn trace_macro_kernel(kernel: &Kernel, lp: &LevelPlan, h: &mut Hierarchy) {
    let views = kernel_views(kernel);
    let gf = GemmForm::of(kernel).expect("GEMM-form kernel");
    let lo = vec![0i64; kernel.n_free()];
    let plan = gf.plan_box(&views, &lo, kernel.extents());
    let mc = lp.mc.clamp(1, plan.m.max(1));
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    // super-band extents from the engine's own normalization, so the
    // trace can never desynchronize from the executed schedule
    let (m3, n3) = crate::codegen::executor::super_band_extents(lp);
    // packed buffers live after the arena, line-aligned, and are reused
    // across super-bands and macro blocks exactly like the real Vecs
    let end = kernel
        .operands()
        .iter()
        .map(|o| o.table.base() + o.table.bytes())
        .max()
        .unwrap();
    let rows_base = end.div_ceil(64) * 64;
    // per row super-band: the mc-block panel lists, exactly as
    // PackedRows::pack_slice_range builds them (panel indices restart at
    // 0 per band — the buffer is reused)
    let mut band_panels: Vec<Vec<Vec<RowPanel>>> = Vec::new();
    let mut i3 = 0usize;
    while i3 < plan.m {
        let m3c = m3.min(plan.m - i3);
        let mut blocks = Vec::new();
        let mut r0 = i3;
        while r0 < i3 + m3c {
            let mcc = mc.min(i3 + m3c - r0);
            blocks.push(plan.row_panels(r0, mcc));
            r0 += mcc;
        }
        band_panels.push(blocks);
        i3 += m3c;
    }
    // buffer bases sized by the widest band and deepest slice; per-slice
    // panel strides below use the clipped kcc, like the real packers
    let max_panels: usize = band_panels
        .iter()
        .map(|b| b.iter().map(|p| p.len()).sum::<usize>())
        .max()
        .unwrap_or(0);
    let cols_base = (rows_base + 8 * max_panels * kc * MR).div_ceil(64) * 64;
    let pt = lp.l1_tile.0.div_ceil(MR).max(1);
    let qt = lp.l1_tile.1.div_ceil(NR).max(1);
    for blocks in &band_panels {
        for j3 in (0..plan.n).step_by(n3) {
            let n3c = n3.min(plan.n - j3);
            for k0 in (0..plan.k).step_by(kc) {
                let kcc = (k0 + kc).min(plan.k) - k0;
                // pack the band's row slice: stream the arena once,
                // write the panels
                let mut gpi = 0usize; // panel index within the band
                for panels in blocks {
                    for p in panels {
                        for t in 0..kcc {
                            for r in 0..p.rows {
                                h.access(8 * (p.row + plan.red_row[k0 + t]) as usize + 8 * r);
                                h.access(rows_base + 8 * (gpi * kcc * MR + t * MR + r));
                            }
                        }
                        gpi += 1;
                    }
                }
                for j0 in (j3..j3 + n3c).step_by(nc) {
                    let ncc = (j0 + nc).min(j3 + n3c) - j0;
                    // pack the column band
                    for q in 0..ncc.div_ceil(NR) {
                        let cols = NR.min(ncc - q * NR);
                        for c in 0..cols {
                            let ci = plan.col_in[j0 + q * NR + c];
                            for t in 0..kcc {
                                h.access(8 * (ci + plan.red_col[k0 + t]) as usize);
                                h.access(cols_base + 8 * (q * kcc * NR + t * NR + c));
                            }
                        }
                    }
                    // macro blocks: L1 tiles over the packed panels,
                    // mirroring dispatch_block's column-tile → row-tile
                    // → q → p nest
                    let mut block_gpi = 0usize;
                    for panels in blocks {
                        let cpanels = ncc.div_ceil(NR);
                        for q0 in (0..cpanels).step_by(qt) {
                            let q_hi = cpanels.min(q0 + qt);
                            for p0 in (0..panels.len()).step_by(pt) {
                                let p_hi = panels.len().min(p0 + pt);
                                for q in q0..q_hi {
                                    let nr = NR.min(ncc - q * NR);
                                    for (pi, p) in
                                        panels.iter().enumerate().take(p_hi).skip(p0)
                                    {
                                        let gpi = block_gpi + pi;
                                        for t in 0..kcc {
                                            for r in 0..MR {
                                                h.access(
                                                    rows_base
                                                        + 8 * (gpi * kcc * MR + t * MR + r),
                                                );
                                            }
                                            for c in 0..NR {
                                                h.access(
                                                    cols_base
                                                        + 8 * (q * kcc * NR + t * NR + c),
                                                );
                                            }
                                        }
                                        for c in 0..nr {
                                            let col = plan.col_out[j0 + q * NR + c];
                                            for r in 0..p.rows {
                                                h.access(8 * (p.out + col) as usize + 8 * r);
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        block_gpi += panels.len();
                    }
                }
            }
        }
    }
}

/// One stage's pack traffic at stage granularity, as the parallel
/// engine's `pack_super_band_stage` issues it: the band's row slice is
/// streamed from the arena into the stage set's row panels, then every
/// `nc` column band of the `j3` range gathers into its own slot of the
/// stage set's column region. (The synchronous serial trace instead
/// packs each column band lazily inside the compute loop; the stage
/// packer fills them all up front so the whole set can be handed over
/// in one move.)
#[allow(clippy::too_many_arguments)]
fn trace_stage_pack(
    h: &mut Hierarchy,
    plan: &RunPlan,
    blocks: &[Vec<RowPanel>],
    rows_base: usize,
    cols_base: usize,
    slot_elems: usize,
    k0: usize,
    kcc: usize,
    j3: usize,
    n3c: usize,
    nc: usize,
) {
    let mut gpi = 0usize;
    for panels in blocks {
        for p in panels {
            for t in 0..kcc {
                for r in 0..p.rows {
                    h.access(8 * (p.row + plan.red_row[k0 + t]) as usize + 8 * r);
                    h.access(rows_base + 8 * (gpi * kcc * MR + t * MR + r));
                }
            }
            gpi += 1;
        }
    }
    for (slot, j0) in (j3..j3 + n3c).step_by(nc).enumerate() {
        let ncc = (j0 + nc).min(j3 + n3c) - j0;
        for q in 0..ncc.div_ceil(NR) {
            let cols = NR.min(ncc - q * NR);
            for c in 0..cols {
                let ci = plan.col_in[j0 + q * NR + c];
                for t in 0..kcc {
                    h.access(8 * (ci + plan.red_col[k0 + t]) as usize);
                    h.access(cols_base + 8 * (slot * slot_elems + q * kcc * NR + t * NR + c));
                }
            }
        }
    }
}

/// One stage's compute traffic: the identical `j0 → L1-tile → q → p`
/// nest as the synchronous trace, reading the stage set's packed panels
/// and accumulating into the output band. Column panels are addressed
/// through their per-band slot in the stage set.
#[allow(clippy::too_many_arguments)]
fn trace_stage_compute(
    h: &mut Hierarchy,
    plan: &RunPlan,
    blocks: &[Vec<RowPanel>],
    rows_base: usize,
    cols_base: usize,
    slot_elems: usize,
    kcc: usize,
    j3: usize,
    n3c: usize,
    nc: usize,
    pt: usize,
    qt: usize,
) {
    for (slot, j0) in (j3..j3 + n3c).step_by(nc).enumerate() {
        let ncc = (j0 + nc).min(j3 + n3c) - j0;
        let mut block_gpi = 0usize;
        for panels in blocks {
            let cpanels = ncc.div_ceil(NR);
            for q0 in (0..cpanels).step_by(qt) {
                let q_hi = cpanels.min(q0 + qt);
                for p0 in (0..panels.len()).step_by(pt) {
                    let p_hi = panels.len().min(p0 + pt);
                    for q in q0..q_hi {
                        let nr = NR.min(ncc - q * NR);
                        for (pi, p) in panels.iter().enumerate().take(p_hi).skip(p0) {
                            let gpi = block_gpi + pi;
                            for t in 0..kcc {
                                for r in 0..MR {
                                    h.access(rows_base + 8 * (gpi * kcc * MR + t * MR + r));
                                }
                                for c in 0..NR {
                                    h.access(
                                        cols_base
                                            + 8 * (slot * slot_elems
                                                + q * kcc * NR
                                                + t * NR
                                                + c),
                                    );
                                }
                            }
                            for c in 0..nr {
                                let col = plan.col_out[j0 + q * NR + c];
                                for r in 0..p.rows {
                                    h.access(8 * (p.out + col) as usize + 8 * r);
                                }
                            }
                        }
                    }
                }
            }
            block_gpi += panels.len();
        }
    }
}

/// Address-level trace of the **pipelined** parallel schedule
/// ([`crate::codegen::ParallelTuning`] with pack-ahead on): within each
/// super-band the worker primes stage `k0 = 0`, then for every stage
/// the companion packer fills the *other* stage set with stage
/// `k0 + kc`'s panels before the worker's stage-`k0` compute accesses
/// are emitted — pack latency leaves the critical path, at the price of
/// a second buffer set's footprint. Emitting the pack-ahead accesses
/// *before* the overlapped compute is the adversarial serialization for
/// a single-trace cache model: the ahead-packed lines get every chance
/// to evict the panels the compute is about to stream, so a "no miss
/// regression" verdict from this trace is conservative. Compute order,
/// and therefore every output element's reduction order, is identical
/// to [`trace_macro_kernel`]'s — only pack placement and packed-buffer
/// addressing differ, so the two traces issue exactly the same number
/// of accesses.
pub fn trace_macro_kernel_pipelined(kernel: &Kernel, lp: &LevelPlan, h: &mut Hierarchy) {
    let views = kernel_views(kernel);
    let gf = GemmForm::of(kernel).expect("GEMM-form kernel");
    let lo = vec![0i64; kernel.n_free()];
    let plan = gf.plan_box(&views, &lo, kernel.extents());
    let mc = lp.mc.clamp(1, plan.m.max(1));
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    let (m3, n3) = crate::codegen::executor::super_band_extents(lp);
    let end = kernel
        .operands()
        .iter()
        .map(|o| o.table.base() + o.table.bytes())
        .max()
        .unwrap();
    // the same per-band mc-block panel lists the synchronous trace builds
    let mut band_panels: Vec<Vec<Vec<RowPanel>>> = Vec::new();
    let mut i3 = 0usize;
    while i3 < plan.m {
        let m3c = m3.min(plan.m - i3);
        let mut blocks = Vec::new();
        let mut r0 = i3;
        while r0 < i3 + m3c {
            let mcc = mc.min(i3 + m3c - r0);
            blocks.push(plan.row_panels(r0, mcc));
            r0 += mcc;
        }
        band_panels.push(blocks);
        i3 += m3c;
    }
    let max_panels: usize = band_panels
        .iter()
        .map(|b| b.iter().map(|p| p.len()).sum::<usize>())
        .max()
        .unwrap_or(0);
    // TWO full stage sets (row panels + one column slot per nc band of a
    // super-band), line-aligned past the arena, alternating by stage
    // parity — the double buffer the pipelined workers circulate
    let rows_bytes = (8 * max_panels * kc * MR).div_ceil(64) * 64;
    let slot_elems = nc.div_ceil(NR) * kc * NR;
    let cols_bytes = 8 * n3.div_ceil(nc) * slot_elems;
    let set_stride = (rows_bytes + cols_bytes).div_ceil(64) * 64;
    let set0 = end.div_ceil(64) * 64;
    let rows_base = |set: usize| set0 + set * set_stride;
    let cols_base = |set: usize| set0 + set * set_stride + rows_bytes;
    let pt = lp.l1_tile.0.div_ceil(MR).max(1);
    let qt = lp.l1_tile.1.div_ceil(NR).max(1);
    let stages: Vec<usize> = (0..plan.k).step_by(kc).collect();
    for blocks in &band_panels {
        for j3 in (0..plan.n).step_by(n3) {
            let n3c = n3.min(plan.n - j3);
            if stages.is_empty() {
                continue;
            }
            // prime: the worker packs stage 0 itself before streaming it
            let kcc0 = kc.min(plan.k - stages[0]);
            trace_stage_pack(
                h,
                &plan,
                blocks,
                rows_base(0),
                cols_base(0),
                slot_elems,
                stages[0],
                kcc0,
                j3,
                n3c,
                nc,
            );
            for (si, &k0) in stages.iter().enumerate() {
                let kcc = (k0 + kc).min(plan.k) - k0;
                // pack-ahead: the companion fills the OTHER set with the
                // next stage while this stage streams
                if si + 1 < stages.len() {
                    let ka = stages[si + 1];
                    let kca = (ka + kc).min(plan.k) - ka;
                    trace_stage_pack(
                        h,
                        &plan,
                        blocks,
                        rows_base((si + 1) % 2),
                        cols_base((si + 1) % 2),
                        slot_elems,
                        ka,
                        kca,
                        j3,
                        n3c,
                        nc,
                    );
                }
                trace_stage_compute(
                    h,
                    &plan,
                    blocks,
                    rows_base(si % 2),
                    cols_base(si % 2),
                    slot_elems,
                    kcc,
                    j3,
                    n3c,
                    nc,
                    pt,
                    qt,
                );
            }
        }
    }
}

pub fn run(sizes: &[i64]) -> Vec<MultiLevelRow> {
    let mut rows = Vec::new();
    for &n in sizes {
        let kernel = ops::matmul(n, n, n, 8, 0);
        let points = (n * n * n) as u64;
        let mut entries: Vec<(String, Box<dyn crate::domain::order::Scanner>)> = vec![
            (
                CompilerAnalog::GccO0.name().to_string(),
                match CompilerAnalog::GccO0.schedule(&kernel) {
                    crate::baseline::AnalogSchedule::Loops(o) => Box::new(o),
                    crate::baseline::AnalogSchedule::Tiled(t) => Box::new(t),
                },
            ),
            (
                CompilerAnalog::GccO3.name().to_string(),
                match CompilerAnalog::GccO3.schedule(&kernel) {
                    crate::baseline::AnalogSchedule::Loops(o) => Box::new(o),
                    crate::baseline::AnalogSchedule::Tiled(t) => Box::new(t),
                },
            ),
        ];
        let (name, plan) = hybrid_plan_for(n, &crate::cache::CacheSpec::HASWELL_L1D);
        entries.push((format!("ours[{name}]"), Box::new(plan)));

        for (strategy, scanner) in entries {
            let mut h = Hierarchy::haswell_l3(Policy::Lru);
            trace_pointwise(&kernel, scanner.as_ref(), &mut h);
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let t0 = Instant::now();
            run_schedule(&mut bufs, &kernel, scanner.as_ref());
            let mops = points as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e6;
            rows.push(MultiLevelRow {
                n,
                strategy,
                l1_misses: h.level(0).stats().misses(),
                l2_misses: h.level(1).stats().misses(),
                l3_misses: h.level(2).stats().misses(),
                est_cycles: h.cost_model(),
                mops,
            });
        }

        // the three-level macro-kernel: simulated trace + real execution
        let lp = macro_plan_for(&kernel);
        let mut h = Hierarchy::haswell_l3(Policy::Lru);
        trace_macro_kernel(&kernel, &lp, &mut h);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let want = bufs.reference();
        let gf = GemmForm::of(&kernel).unwrap();
        let rplan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
        let t0 = Instant::now();
        run_macro(
            &mut bufs.arena,
            &rplan,
            &lp,
            MicroShape::Mr8Nr4,
            &mut PackedRows::<f64>::new(),
            &mut PackedCols::<f64>::new(),
        );
        let mops = points as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e6;
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "macro-kernel diverged from the oracle at n={n}"
        );
        rows.push(MultiLevelRow {
            n,
            strategy: "macro-kernel".to_string(),
            l1_misses: h.level(0).stats().misses(),
            l2_misses: h.level(1).stats().misses(),
            l3_misses: h.level(2).stats().misses(),
            est_cycles: h.cost_model(),
            mops,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_tiling_also_helps_l2_and_cycles() {
        let rows = run(&[96]);
        let o0 = rows.iter().find(|r| r.strategy.contains("O0")).unwrap();
        let ours = rows.iter().find(|r| r.strategy.starts_with("ours")).unwrap();
        // L1-optimal tiling reduces L1 misses and must not inflate L2
        // misses beyond the naive order's
        assert!(ours.l1_misses < o0.l1_misses);
        assert!(ours.l2_misses <= o0.l2_misses * 2);
        // and wins the latency-model estimate
        assert!(ours.est_cycles < o0.est_cycles);
    }

    #[test]
    fn l2_misses_bounded_by_l1_misses() {
        // inclusive hierarchy: L2 only sees L1 misses
        for r in run(&[64]) {
            assert!(r.l2_misses <= r.l1_misses, "{}", r.strategy);
        }
    }

    #[test]
    fn macro_kernel_lowers_l2_misses_at_l2_exceeding_sizes() {
        // at n=160 the 3·n²·8 B arena is ~2.3× the 256 KiB L2, so the
        // single-level plan re-streams operands through L2 while the
        // macro-kernel's packed blocks stay resident
        let n = 160i64;
        let kernel = ops::matmul(n, n, n, 8, 0);
        let (_, plan) = hybrid_plan_for(n, &CacheSpec::HASWELL_L1D);
        let mut h1 = Hierarchy::haswell(Policy::Lru);
        trace_pointwise(&kernel, &plan, &mut h1);
        let mut h2 = Hierarchy::haswell(Policy::Lru);
        let lp = macro_plan_for(&kernel);
        trace_macro_kernel(&kernel, &lp, &mut h2);
        let single = h1.level(1).stats().misses();
        let multi = h2.level(1).stats().misses();
        assert!(
            multi < single,
            "macro-kernel L2 misses {multi} not below single-level {single}"
        );
    }

    #[test]
    fn super_bands_cut_l3_misses_when_flat_bands_thrash() {
        // m×kc = 4608×64 f64 = 2.25 MiB of packed row panels: the flat
        // (single-super-band) schedule streams them through the 2 MiB L3
        // slice once per column band, so the second band re-misses the
        // whole slice; 512-row super-bands keep each band's 256 KiB row
        // slice L3-resident across its column bands
        let (m, k, n) = (4608i64, 64, 64);
        let kernel = ops::matmul(m, k, n, 8, 0);
        let flat = LevelPlan {
            l1_tile: (32, 32, 32),
            mc: 64,
            kc: 64,
            nc: 32,
            m3: 4608,
            n3: 64,
        };
        let sup = LevelPlan {
            m3: 512,
            ..flat
        };
        let mut hf = Hierarchy::haswell_l3(Policy::Lru);
        trace_macro_kernel(&kernel, &flat, &mut hf);
        let mut hs = Hierarchy::haswell_l3(Policy::Lru);
        trace_macro_kernel(&kernel, &sup, &mut hs);
        let flat_l3 = hf.level(2).stats().misses();
        let sup_l3 = hs.level(2).stats().misses();
        assert!(
            sup_l3 < flat_l3,
            "super-band L3 misses {sup_l3} not below flat-band {flat_l3}"
        );
        // the super-band schedule issues *more* accesses (column bands
        // repack once per row super-band) yet misses L3 less — the win
        // is locality, not less work
        assert!(hs.level(0).stats().accesses > hf.level(0).stats().accesses);
    }

    #[test]
    fn pipelined_schedule_adds_no_l2_l3_miss_regression() {
        // 72 super-bands × 4 kc stages, sized so the double-buffered
        // stage sets (~64 KiB both sets) sit comfortably inside L2 while
        // the 4.5 MiB input matrix streams past both caches. The
        // pipelined trace must issue exactly the synchronous schedule's
        // access count (packing is reordered and double-buffered, never
        // duplicated), and may cost at most the second stage set's cold
        // lines — gated at 5% on modelled L2 and L3 misses against both
        // the synchronous super-band schedule and the flat single-band
        // one, per level
        let (m, k, n) = (4608i64, 128, 64);
        let kernel = ops::matmul(m, k, n, 8, 0);
        let sup = LevelPlan {
            l1_tile: (32, 32, 32),
            mc: 64,
            kc: 32,
            nc: 32,
            m3: 64,
            n3: 64,
        };
        let flat = LevelPlan { m3: 4608, ..sup };
        let mut hs = Hierarchy::haswell_l3(Policy::Lru);
        trace_macro_kernel(&kernel, &sup, &mut hs);
        let mut hp = Hierarchy::haswell_l3(Policy::Lru);
        trace_macro_kernel_pipelined(&kernel, &sup, &mut hp);
        let mut hf = Hierarchy::haswell_l3(Policy::Lru);
        trace_macro_kernel(&kernel, &flat, &mut hf);
        assert_eq!(
            hp.level(0).stats().accesses,
            hs.level(0).stats().accesses,
            "pipelining reorders the schedule, it must not change its work"
        );
        for lvl in [1usize, 2] {
            let p = hp.level(lvl).stats().misses();
            let s = hs.level(lvl).stats().misses();
            let f = hf.level(lvl).stats().misses();
            assert!(
                p * 100 <= s * 105,
                "L{} pipelined misses {p} regressed past synchronous {s}",
                lvl + 1
            );
            assert!(
                p * 100 <= f * 105,
                "L{} pipelined misses {p} regressed past flat {f}",
                lvl + 1
            );
        }
    }

    #[test]
    fn generalized_trace_covers_convolution_and_kronecker() {
        // the tracer must walk the same structures the engine executes —
        // for every Table-1 kernel, not just matmul
        for kernel in [
            ops::convolution(4096, 8, 0),
            ops::kronecker(12, 12, 16, 16, 8, 0),
        ] {
            let lp = macro_plan_for(&kernel);
            let mut h = Hierarchy::haswell(Policy::Lru);
            trace_macro_kernel(&kernel, &lp, &mut h);
            assert!(h.level(0).stats().accesses > 0, "{}", kernel.name());
            assert!(
                h.level(1).stats().misses() <= h.level(0).stats().misses(),
                "{}",
                kernel.name()
            );
        }
    }
}
