//! Extension (paper §4 future work): multi-level behaviour of the chosen
//! tilings — and of the macro-kernel that now exploits it.
//!
//! The paper tiles for a single level (L1) and defers multi-level tiling.
//! This experiment quantifies both sides: each plan runs through a
//! two-level Haswell hierarchy (L1d 32 KiB/8-way + L2 256 KiB/8-way) and
//! reports per-level misses, and the two-level **macro-kernel**
//! (`run_macro_matmul`) is traced at address level — pack reads stream
//! the arena once per macro block, micro-kernel reads hit the packed
//! panels (which get their own simulated addresses past the arena) — so
//! its L2 advantage over the single-level plans is *measured*, not
//! asserted. Rows also carry executed Mops/s so the simulated and real
//! orderings can be compared.

use std::time::Instant;

use crate::baseline::CompilerAnalog;
use crate::cache::{CacheSpec, Hierarchy, Policy};
use crate::codegen::executor::{max_abs_diff, run_macro_matmul, run_schedule, MatmulBuffers};
use crate::codegen::pack::{PackedB, PackedC};
use crate::codegen::{MR, NR};
use crate::domain::ops;
use crate::domain::order::Scanner;
use crate::domain::Kernel;
use crate::experiments::fig4::hybrid_plan_for;
use crate::tiling::LevelPlan;

#[derive(Clone, Debug)]
pub struct MultiLevelRow {
    pub n: i64,
    pub strategy: String,
    pub l1_misses: u64,
    pub l2_misses: u64,
    /// Simple cycle estimate from the hierarchy's latency model.
    pub est_cycles: u64,
    /// Executed throughput of the strategy (lattice points per second,
    /// in millions), measured on real buffers.
    pub mops: f64,
}

/// Per-point address trace of a scanner-driven schedule (A, B, C per
/// visited point, write-allocate output).
pub fn trace_pointwise(kernel: &Kernel, scanner: &dyn Scanner, h: &mut Hierarchy) {
    let bases: Vec<usize> = kernel.operands().iter().map(|o| o.table.base()).collect();
    let lds: Vec<usize> = kernel
        .operands()
        .iter()
        .map(|o| o.table.map().weights()[1] as usize)
        .collect();
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
        h.access(bases[0] + 8 * (i + lds[0] * j));
        h.access(bases[1] + 8 * (i + lds[1] * kk));
        h.access(bases[2] + 8 * (kk + lds[2] * j));
    });
}

/// The macro shape this experiment simulates: quarter-L2 packed B and C
/// blocks, so both stay resident together with the output band during a
/// macro block (the modelled hierarchy has no L3, so `nc` is bounded the
/// same way as `mc`).
pub fn macro_plan_for(kernel: &Kernel) -> LevelPlan {
    let extents = kernel.extents();
    let (m, n, k) = (
        extents[0] as usize,
        extents[1] as usize,
        extents[2] as usize,
    );
    let quarter = CacheSpec::HASWELL_L2.capacity / (4 * 8);
    let kc = k.clamp(1, 128);
    let mc = ((quarter / kc).max(MR) / MR * MR).min(m.div_ceil(MR) * MR);
    let nc = ((quarter / kc).max(NR) / NR * NR).min(n.div_ceil(NR) * NR);
    LevelPlan {
        l1_tile: (32.min(m.max(1)), 32.min(n.max(1)), 32.min(k.max(1))),
        mc,
        kc,
        nc,
    }
}

/// Address-level trace of the two-level macro-kernel, mirroring
/// `run_macro_matmul` exactly: pack reads/writes touch the arena and the
/// packed buffers (placed line-aligned past the arena), the micro-kernel
/// reads only packed panels, and each output element is touched once per
/// register block per k slice.
pub fn trace_macro_kernel(kernel: &Kernel, lp: &LevelPlan, h: &mut Hierarchy) {
    let operands = kernel.operands();
    let a_base = operands[0].table.base();
    let b_base = operands[1].table.base();
    let c_base = operands[2].table.base();
    let lda = operands[0].table.map().weights()[1] as usize;
    let ldb = operands[1].table.map().weights()[1] as usize;
    let ldc = operands[2].table.map().weights()[1] as usize;
    let extents = kernel.extents();
    let (m, n, k) = (
        extents[0] as usize,
        extents[1] as usize,
        extents[2] as usize,
    );
    let mc = lp.mc.max(1).min(m);
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    // packed buffers live after the arena, line-aligned, and are reused
    // across macro blocks exactly like the real Vec allocations
    let end = operands
        .iter()
        .map(|o| o.table.base() + o.table.bytes())
        .max()
        .unwrap();
    let bp_base = end.div_ceil(64) * 64;
    let n_blocks = m.div_ceil(mc);
    // buffer bases sized by the deepest (full-kc) slice; per-slice panel
    // strides below use the clipped kcc, exactly like the real packers
    let full_stride = mc.div_ceil(MR) * kc * MR;
    let cp_base = (bp_base + 8 * n_blocks * full_stride).div_ceil(64) * 64;
    let ti = lp.l1_tile.0.div_ceil(MR).max(1) * MR;
    let tj = lp.l1_tile.1.div_ceil(NR).max(1) * NR;
    for k0 in (0..k).step_by(kc) {
        let kcc = (k0 + kc).min(k) - k0;
        let block_stride = mc.div_ceil(MR) * kcc * MR;
        // pack the B slice: stream the arena once, write the panels
        for bi in 0..n_blocks {
            let i0 = bi * mc;
            let mcc = mc.min(m - i0);
            for p in 0..mcc.div_ceil(MR) {
                let rows = MR.min(mcc - p * MR);
                for t in 0..kcc {
                    for r in 0..rows {
                        h.access(b_base + 8 * (i0 + p * MR + r + ldb * (k0 + t)));
                        h.access(bp_base + 8 * (bi * block_stride + p * kcc * MR + t * MR + r));
                    }
                }
            }
        }
        for j0 in (0..n).step_by(nc) {
            let ncc = (j0 + nc).min(n) - j0;
            // pack the C block of this column band
            for q in 0..ncc.div_ceil(NR) {
                let cols = NR.min(ncc - q * NR);
                for c in 0..cols {
                    for t in 0..kcc {
                        h.access(c_base + 8 * (k0 + t + ldc * (j0 + q * NR + c)));
                        h.access(cp_base + 8 * (q * kcc * NR + t * NR + c));
                    }
                }
            }
            // macro block: L1 tiles over the packed panels
            for bi in 0..n_blocks {
                let i0 = bi * mc;
                let mcc = mc.min(m - i0);
                let bpanels = mcc.div_ceil(MR);
                let cpanels = ncc.div_ceil(NR);
                for jt in (0..ncc).step_by(tj) {
                    let q_hi = cpanels.min((jt + tj) / NR);
                    for it in (0..mcc).step_by(ti) {
                        let p_hi = bpanels.min((it + ti) / MR);
                        for q in (jt / NR)..q_hi {
                            let nr = NR.min(ncc - q * NR);
                            for p in (it / MR)..p_hi {
                                let mr = MR.min(mcc - p * MR);
                                for t in 0..kcc {
                                    for r in 0..MR {
                                        h.access(
                                            bp_base
                                                + 8 * (bi * block_stride
                                                    + p * kcc * MR
                                                    + t * MR
                                                    + r),
                                        );
                                    }
                                    for c in 0..NR {
                                        h.access(cp_base + 8 * (q * kcc * NR + t * NR + c));
                                    }
                                }
                                for c in 0..nr {
                                    for r in 0..mr {
                                        h.access(
                                            a_base
                                                + 8 * (i0
                                                    + p * MR
                                                    + r
                                                    + lda * (j0 + q * NR + c)),
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

pub fn run(sizes: &[i64]) -> Vec<MultiLevelRow> {
    let mut rows = Vec::new();
    for &n in sizes {
        let kernel = ops::matmul(n, n, n, 8, 0);
        let points = (n * n * n) as u64;
        let mut entries: Vec<(String, Box<dyn crate::domain::order::Scanner>)> = vec![
            (
                CompilerAnalog::GccO0.name().to_string(),
                match CompilerAnalog::GccO0.schedule(&kernel) {
                    crate::baseline::AnalogSchedule::Loops(o) => Box::new(o),
                    crate::baseline::AnalogSchedule::Tiled(t) => Box::new(t),
                },
            ),
            (
                CompilerAnalog::GccO3.name().to_string(),
                match CompilerAnalog::GccO3.schedule(&kernel) {
                    crate::baseline::AnalogSchedule::Loops(o) => Box::new(o),
                    crate::baseline::AnalogSchedule::Tiled(t) => Box::new(t),
                },
            ),
        ];
        let (name, plan) = hybrid_plan_for(n, &crate::cache::CacheSpec::HASWELL_L1D);
        entries.push((format!("ours[{name}]"), Box::new(plan)));

        for (strategy, scanner) in entries {
            let mut h = Hierarchy::haswell(Policy::Lru);
            trace_pointwise(&kernel, scanner.as_ref(), &mut h);
            let mut bufs = MatmulBuffers::from_kernel(&kernel);
            let t0 = Instant::now();
            run_schedule(&mut bufs, &kernel, scanner.as_ref());
            let mops = points as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e6;
            rows.push(MultiLevelRow {
                n,
                strategy,
                l1_misses: h.level(0).stats().misses(),
                l2_misses: h.level(1).stats().misses(),
                est_cycles: h.cost_model(),
                mops,
            });
        }

        // the two-level macro-kernel: simulated trace + real execution
        let lp = macro_plan_for(&kernel);
        let mut h = Hierarchy::haswell(Policy::Lru);
        trace_macro_kernel(&kernel, &lp, &mut h);
        let mut bufs = MatmulBuffers::from_kernel(&kernel);
        let want = bufs.reference();
        let geom = bufs.geom();
        let dims = (n as usize, n as usize, n as usize);
        let t0 = Instant::now();
        run_macro_matmul(
            &mut bufs.arena,
            geom,
            dims,
            &lp,
            &mut PackedB::new(),
            &mut PackedC::new(),
        );
        let mops = points as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e6;
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "macro-kernel diverged from the oracle at n={n}"
        );
        rows.push(MultiLevelRow {
            n,
            strategy: "macro-kernel".to_string(),
            l1_misses: h.level(0).stats().misses(),
            l2_misses: h.level(1).stats().misses(),
            est_cycles: h.cost_model(),
            mops,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_tiling_also_helps_l2_and_cycles() {
        let rows = run(&[96]);
        let o0 = rows.iter().find(|r| r.strategy.contains("O0")).unwrap();
        let ours = rows.iter().find(|r| r.strategy.starts_with("ours")).unwrap();
        // L1-optimal tiling reduces L1 misses and must not inflate L2
        // misses beyond the naive order's
        assert!(ours.l1_misses < o0.l1_misses);
        assert!(ours.l2_misses <= o0.l2_misses * 2);
        // and wins the latency-model estimate
        assert!(ours.est_cycles < o0.est_cycles);
    }

    #[test]
    fn l2_misses_bounded_by_l1_misses() {
        // inclusive hierarchy: L2 only sees L1 misses
        for r in run(&[64]) {
            assert!(r.l2_misses <= r.l1_misses, "{}", r.strategy);
        }
    }

    #[test]
    fn macro_kernel_lowers_l2_misses_at_l2_exceeding_sizes() {
        // at n=160 the 3·n²·8 B arena is ~2.3× the 256 KiB L2, so the
        // single-level plan re-streams operands through L2 while the
        // macro-kernel's packed blocks stay resident
        let n = 160i64;
        let kernel = ops::matmul(n, n, n, 8, 0);
        let (_, plan) = hybrid_plan_for(n, &CacheSpec::HASWELL_L1D);
        let mut h1 = Hierarchy::haswell(Policy::Lru);
        trace_pointwise(&kernel, &plan, &mut h1);
        let mut h2 = Hierarchy::haswell(Policy::Lru);
        let lp = macro_plan_for(&kernel);
        trace_macro_kernel(&kernel, &lp, &mut h2);
        let single = h1.level(1).stats().misses();
        let multi = h2.level(1).stats().misses();
        assert!(
            multi < single,
            "macro-kernel L2 misses {multi} not below single-level {single}"
        );
    }
}
