//! Extension (paper §4 future work): multi-level behaviour of the chosen
//! tilings.
//!
//! The paper tiles for a single level (L1) and defers multi-level tiling.
//! This experiment quantifies what that leaves on the table: we run each
//! plan through a two-level Haswell hierarchy (L1d 32 KiB/8-way +
//! L2 256 KiB/8-way) and report per-level misses. An L1-optimal tile
//! whose working set blows L2 would show here; conversely it demonstrates
//! that L2 absorbs the L1 conflicts of the *untiled* orders only partially
//! — motivating (as the paper anticipates) hierarchical lattice tiling.

use crate::baseline::CompilerAnalog;
use crate::cache::{Hierarchy, Policy};
use crate::domain::ops;
use crate::experiments::fig4::hybrid_plan_for;

#[derive(Clone, Debug)]
pub struct MultiLevelRow {
    pub n: i64,
    pub strategy: String,
    pub l1_misses: u64,
    pub l2_misses: u64,
    /// Simple cycle estimate from the hierarchy's latency model.
    pub est_cycles: u64,
}

pub fn run(sizes: &[i64]) -> Vec<MultiLevelRow> {
    let mut rows = Vec::new();
    for &n in sizes {
        let kernel = ops::matmul(n, n, n, 8, 0);
        let mut entries: Vec<(String, Box<dyn crate::domain::order::Scanner>)> = vec![
            (
                CompilerAnalog::GccO0.name().to_string(),
                match CompilerAnalog::GccO0.schedule(&kernel) {
                    crate::baseline::AnalogSchedule::Loops(o) => Box::new(o),
                    crate::baseline::AnalogSchedule::Tiled(t) => Box::new(t),
                },
            ),
            (
                CompilerAnalog::GccO3.name().to_string(),
                match CompilerAnalog::GccO3.schedule(&kernel) {
                    crate::baseline::AnalogSchedule::Loops(o) => Box::new(o),
                    crate::baseline::AnalogSchedule::Tiled(t) => Box::new(t),
                },
            ),
        ];
        let (name, plan) = hybrid_plan_for(n, &crate::cache::CacheSpec::HASWELL_L1D);
        entries.push((format!("ours[{name}]"), Box::new(plan)));

        for (strategy, scanner) in entries {
            let mut h = Hierarchy::haswell(Policy::Lru);
            let bases: Vec<usize> = kernel.operands().iter().map(|o| o.table.base()).collect();
            let lds: Vec<usize> = kernel
                .operands()
                .iter()
                .map(|o| o.table.map().weights()[1] as usize)
                .collect();
            scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
                let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
                h.access(bases[0] + 8 * (i + lds[0] * j));
                h.access(bases[1] + 8 * (i + lds[1] * kk));
                h.access(bases[2] + 8 * (kk + lds[2] * j));
            });
            rows.push(MultiLevelRow {
                n,
                strategy,
                l1_misses: h.level(0).stats().misses(),
                l2_misses: h.level(1).stats().misses(),
                est_cycles: h.cost_model(),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_tiling_also_helps_l2_and_cycles() {
        let rows = run(&[96]);
        let o0 = rows.iter().find(|r| r.strategy.contains("O0")).unwrap();
        let ours = rows.iter().find(|r| r.strategy.starts_with("ours")).unwrap();
        // L1-optimal tiling reduces L1 misses and must not inflate L2
        // misses beyond the naive order's
        assert!(ours.l1_misses < o0.l1_misses);
        assert!(ours.l2_misses <= o0.l2_misses * 2);
        // and wins the latency-model estimate
        assert!(ours.est_cycles < o0.est_cycles);
    }

    #[test]
    fn l2_misses_bounded_by_l1_misses() {
        // inclusive hierarchy: L2 only sees L1 misses
        for r in run(&[64]) {
            assert!(r.l2_misses <= r.l1_misses, "{}", r.strategy);
        }
    }
}
