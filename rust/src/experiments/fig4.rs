//! Figure 4 / E5, E6: lattice tiling vs compiler-analog baselines, and
//! best-rectangular vs best-lattice tilings.
//!
//! For each matmul size we measure, per strategy: simulated Haswell-L1d
//! misses (line-granular, LRU) and executor wallclock on this machine.
//! Expected shape (not absolute numbers — see DESIGN.md §3): lattice
//! ≫ `-O0` (10–20×), lattice > `-O2` (2–6×), lattice ≈ `icc`, and the
//! advantage concentrates on pathological power-of-two leading dimensions.

use std::time::Duration;

use crate::baseline::CompilerAnalog;
use crate::cache::{CacheSim, CacheSpec, Policy};
use crate::codegen::executor::{KernelBuffers, TiledExecutor};
use crate::codegen::run_trace_only;
use crate::domain::{ops, Kernel};
use crate::tiling::{self, TiledSchedule};

use super::harness::time_reps;

/// One measured row.
#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub n: i64,
    pub strategy: String,
    pub l1_misses: u64,
    pub wall: Duration,
    pub gflops: f64,
}

/// Select the lattice plan for a full-size matmul by running the paper's
/// selector on a size-capped instance with the **true leading dimensions**
/// (the conflict lattice depends on lda, not on the iteration extents).
pub fn lattice_plan_for(n: i64, spec: &CacheSpec) -> TiledSchedule {
    let cap = 64i64.min(n);
    let small = ops::matmul_padded(cap, cap, cap, n, n, n, 8, 0);
    let ranked = tiling::select(&small, spec, 8);
    let plan = ranked
        .into_iter()
        .find(|p| p.lattice_operand.is_some())
        .expect("a lattice plan exists for matmul");
    TiledSchedule::new(plan.schedule.basis().clone())
}

/// The framework's hybrid choice (§4.0.4): best plan overall — lattice or
/// rectangular — under the sampled model. This is what `latticetile plan`
/// would deploy.
pub fn hybrid_plan_for(n: i64, spec: &CacheSpec) -> (String, TiledSchedule) {
    let cap = 64i64.min(n);
    let small = ops::matmul_padded(cap, cap, cap, n, n, n, 8, 0);
    let ranked = tiling::select(&small, spec, 8);
    let best = ranked.into_iter().next().expect("candidates");
    (
        best.name.clone(),
        TiledSchedule::new(best.schedule.basis().clone()),
    )
}

/// Best rectangular plan under the same (sampled-model) scoring.
pub fn best_rect_plan_for(n: i64, spec: &CacheSpec) -> (String, TiledSchedule) {
    let cap = 64i64.min(n);
    let small = ops::matmul_padded(cap, cap, cap, n, n, n, 8, 0);
    let cands = tiling::rect_candidates(&small, spec);
    let ranked = tiling::model_driven_search(&small, spec, cands, 8);
    let best = ranked.into_iter().next().expect("rect candidates");
    (
        best.name.clone(),
        TiledSchedule::new(best.schedule.basis().clone()),
    )
}

fn sim_misses(kernel: &Kernel, scanner: &dyn crate::domain::order::Scanner) -> u64 {
    let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru).without_classification();
    run_trace_only(kernel, scanner, &mut sim);
    sim.stats().misses()
}

/// Run the Figure 4 comparison for one size; `reps` timing repetitions.
pub fn run_size(n: i64, reps: usize) -> Vec<Fig4Row> {
    let spec = CacheSpec::HASWELL_L1D;
    let kernel = ops::matmul(n, n, n, 8, 0);
    let flops = 2.0 * (n as f64).powi(3);
    let mut rows = Vec::new();

    for analog in CompilerAnalog::ALL {
        let sched = analog.schedule(&kernel);
        let misses = sim_misses(&kernel, sched.as_scanner());
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let (wall, _) = time_reps(reps, || {
            bufs.reset_output();
            analog.execute(&mut bufs, &kernel);
        });
        rows.push(Fig4Row {
            n,
            strategy: analog.name().to_string(),
            l1_misses: misses,
            wall,
            gflops: flops / wall.as_secs_f64() / 1e9,
        });
    }

    // ours: the framework's hybrid model-driven choice (§4.0.4), plus the
    // pure K−1 lattice plan for reference
    let (hybrid_name, hybrid) = hybrid_plan_for(n, &spec);
    let lattice = lattice_plan_for(n, &spec);
    for (tag, plan) in [
        (format!("ours[{hybrid_name}]"), hybrid),
        ("ours-lattice(K-1)".to_string(), lattice),
    ] {
        let misses = sim_misses(&kernel, &plan);
        let exec = TiledExecutor::new(plan);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let (wall, _) = time_reps(reps, || {
            bufs.reset_output();
            exec.run(&mut bufs, &kernel);
        });
        rows.push(Fig4Row {
            n,
            strategy: tag,
            l1_misses: misses,
            wall,
            gflops: flops / wall.as_secs_f64() / 1e9,
        });
    }

    rows
}

/// E6: best-rect vs best-lattice, miss counts + wallclock.
pub fn run_rect_vs_lattice(n: i64, reps: usize) -> Vec<Fig4Row> {
    let spec = CacheSpec::HASWELL_L1D;
    let kernel = ops::matmul(n, n, n, 8, 0);
    let flops = 2.0 * (n as f64).powi(3);
    let mut rows = Vec::new();

    let (rect_name, rect_plan) = best_rect_plan_for(n, &spec);
    let lattice_plan = lattice_plan_for(n, &spec);

    for (name, plan) in [(rect_name, rect_plan), ("lattice(K-1)".into(), lattice_plan)] {
        let misses = sim_misses(&kernel, &plan);
        let exec = TiledExecutor::new(plan);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let (wall, _) = time_reps(reps, || {
            bufs.reset_output();
            exec.run(&mut bufs, &kernel);
        });
        rows.push(Fig4Row {
            n,
            strategy: name,
            l1_misses: misses,
            wall,
            gflops: flops / wall.as_secs_f64() / 1e9,
        });
    }
    rows
}

/// Speedup of every row vs the named baseline (by wallclock).
pub fn speedups_vs(rows: &[Fig4Row], baseline: &str) -> Vec<(String, f64)> {
    let base = rows
        .iter()
        .find(|r| r.strategy == baseline)
        .map(|r| r.wall.as_secs_f64())
        .unwrap_or(f64::NAN);
    rows.iter()
        .map(|r| (r.strategy.clone(), base / r.wall.as_secs_f64()))
        .collect()
}

/// Miss-count ratio of every row vs the named baseline.
pub fn miss_ratios_vs(rows: &[Fig4Row], baseline: &str) -> Vec<(String, f64)> {
    let base = rows
        .iter()
        .find(|r| r.strategy == baseline)
        .map(|r| r.l1_misses as f64)
        .unwrap_or(f64::NAN);
    rows.iter()
        .map(|r| (r.strategy.clone(), base / r.l1_misses as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_plan_covers_domain() {
        use crate::domain::order::Scanner;
        let plan = lattice_plan_for(96, &CacheSpec::HASWELL_L1D);
        let k = ops::matmul(96, 96, 96, 8, 0);
        let mut n = 0usize;
        plan.scan_points(k.extents(), &mut |_: &[i64]| n += 1);
        assert_eq!(n, 96 * 96 * 96);
    }

    #[test]
    fn lattice_beats_naive_on_pathological_size() {
        // n = 128: power-of-two lda → severe conflicts for naive and for
        // fixed 64³ rect tiles; the lattice plan must beat gcc-O0 on
        // simulated misses by a wide margin.
        let n = 128i64;
        let kernel = ops::matmul(n, n, n, 8, 0);
        let o0 = CompilerAnalog::GccO0.schedule(&kernel);
        let naive = sim_misses(&kernel, o0.as_scanner());
        let plan = lattice_plan_for(n, &CacheSpec::HASWELL_L1D);
        let ours = sim_misses(&kernel, &plan);
        assert!(
            (ours as f64) < naive as f64 / 4.0,
            "lattice {ours} vs naive {naive}"
        );
    }

    #[test]
    fn lattice_result_is_numerically_correct() {
        let n = 96i64;
        let kernel = ops::matmul(n, n, n, 8, 0);
        let plan = lattice_plan_for(n, &CacheSpec::HASWELL_L1D);
        let exec = TiledExecutor::new(plan);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let want = bufs.reference();
        exec.run(&mut bufs, &kernel);
        assert!(crate::codegen::max_abs_diff(&want, &bufs.output()) < 1e-9);
    }
}
