//! Minimal property-testing support (proptest is unavailable offline):
//! a deterministic xorshift PRNG + a `prop_check` driver that reports the
//! failing seed/case so failures are reproducible.

/// Deterministic xorshift64* PRNG.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range_usize(0, items.len() - 1)]
    }
}

/// Run `body` over `cases` generated cases; panics with the case index on
/// the first failure (body should panic/assert internally).
pub fn prop_check<F: FnMut(usize, &mut Rng)>(cases: usize, seed: u64, mut body: F) {
    for case in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(case as u64 * 0x9E3779B97F4A7C15));
        body(case, &mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds_hold() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range_i64(-3, 9);
            assert!((-3..=9).contains(&v));
        }
    }
}
