//! Potential conflicts — §2.3.
//!
//! For a cache `C` with set period `P` (in elements) and an operand with
//! affine index map `φ`, two elements are in **potential conflict** iff
//! their linear indices are congruent mod `P` (Definition 7). The set of
//! index-space differences realizing this is the lattice
//! `L(C, φ) = {x : φ(x) ≡ 0 (mod P)}` (Observation 1), which we construct
//! in closed form — no lattice-point counting — via
//! [`Lattice::from_congruence`].
//!
//! ## Granularity
//!
//! Definition 7 works at *element* granularity (`i ≡ j mod N`), i.e. it
//! implicitly assumes one element per cacheline. For `l > elem` we use the
//! element-stride period `P = c / (K·elem)`: elements exactly `P` apart
//! share both set and line offset, which preserves the affine lattice
//! structure. Sub-line spatial effects are *deliberately* outside the
//! model (the paper discusses them separately in Figure 5); the cache
//! simulator measures them for real.

use crate::cache::CacheSpec;
use crate::domain::Kernel;
use crate::lattice::Lattice;

/// Conflict-lattice data for one operand of a kernel.
#[derive(Clone, Debug)]
pub struct OperandConflicts {
    /// `L(C, φ)` in the operand's own index space (Definition 7 /
    /// Observation 1).
    pub operand_lattice: Lattice,
    /// `Λ(A_i)` pulled back to the *loop* space through the access
    /// function (§2.4): `{f : w·f ≡ 0 (mod P)}` for the composed weights
    /// `w` of `φ ∘ access`. `None` when the composed weights vanish
    /// entirely mod `P` (constant accesses — every loop point touches the
    /// same class).
    pub loop_lattice: Option<Lattice>,
    /// Composed linear weights of `φ ∘ access` on the loop variables.
    pub loop_weights: Vec<i64>,
    /// Affine offset of `φ ∘ access` including the table base address —
    /// `φ(q_A)` in the paper's notation; the conflict-class residue of the
    /// operand's element 0 is `offset mod P`.
    pub offset: i64,
}

/// Conflict analysis of a whole kernel under one cache spec.
#[derive(Clone, Debug)]
pub struct ConflictAnalysis {
    /// Set period in elements: `P = c / (K·elem)`.
    pub period: i64,
    /// Cache associativity `K`.
    pub ways: usize,
    /// Elements per cacheline (`l / elem`).
    pub gran: i64,
    /// Number of cache sets `N = c/(l·K)` — the line-granular class count
    /// (`period == gran · n_classes`).
    pub n_classes: i64,
    pub operands: Vec<OperandConflicts>,
}

impl ConflictAnalysis {
    /// Analyze `kernel` under `spec`. All operands must share one element
    /// size (the usual case; mixed sizes would need per-operand periods).
    pub fn new(kernel: &Kernel, spec: &CacheSpec) -> ConflictAnalysis {
        let elem = kernel.operand(0).table.elem();
        assert!(
            kernel.operands().iter().all(|o| o.table.elem() == elem),
            "mixed element sizes not supported"
        );
        assert_eq!(spec.line % elem, 0, "element must divide cacheline");
        let period = (spec.capacity / (spec.ways * elem)) as i64;

        let operands = kernel
            .operands()
            .iter()
            .map(|op| {
                let phi = op.table.map();
                // operand-space lattice from φ's own weights
                let w128: Vec<i128> = phi.weights_i128();
                let operand_lattice = Lattice::from_congruence(&w128, period as i128);
                // loop-space lattice from composed weights (φ ∘ access),
                // including the byte base address folded into the offset
                let base_elems = (op.table.base() / elem) as i64;
                let (w, o) = op
                    .access
                    .compose_weights(phi.weights(), phi.offset() + base_elems);
                let all_zero_mod = w.iter().all(|&wi| (wi as i128).rem_euclid(period as i128) == 0);
                let loop_lattice = if all_zero_mod {
                    None
                } else {
                    let w128: Vec<i128> = w.iter().map(|&x| x as i128).collect();
                    Some(Lattice::from_congruence(&w128, period as i128))
                };
                OperandConflicts {
                    operand_lattice,
                    loop_lattice,
                    loop_weights: w,
                    offset: o,
                }
            })
            .collect();

        ConflictAnalysis {
            period,
            ways: spec.ways,
            gran: (spec.line / elem) as i64,
            n_classes: spec.n_sets() as i64,
            operands,
        }
    }

    /// The conflict class (set-class residue mod `P`) operand `p` touches
    /// at loop point `f`.
    pub fn class_at(&self, p: usize, f: &[i64]) -> i64 {
        let oc = &self.operands[p];
        let lin: i64 = oc.offset
            + oc.loop_weights
                .iter()
                .zip(f)
                .map(|(&w, &x)| w * x)
                .sum::<i64>();
        lin.rem_euclid(self.period)
    }

    /// Element (linear index incl. base) operand `p` touches at `f`.
    pub fn element_at(&self, p: usize, f: &[i64]) -> i64 {
        let oc = &self.operands[p];
        oc.offset
            + oc.loop_weights
                .iter()
                .zip(f)
                .map(|(&w, &x)| w * x)
                .sum::<i64>()
    }

    /// Cacheline id operand `p` touches at loop point `f` (element index
    /// floor-divided by the line granularity — the unit the real cache
    /// moves; table bases are element-aligned by construction).
    pub fn line_at(&self, p: usize, f: &[i64]) -> i64 {
        self.element_at(p, f).div_euclid(self.gran)
    }

    /// The cache *set* (line-granular conflict class) operand `p` touches
    /// at loop point `f` — exactly the hardware's set index.
    pub fn line_class_at(&self, p: usize, f: &[i64]) -> i64 {
        self.line_at(p, f).rem_euclid(self.n_classes)
    }

    /// The potential-conflict index-set `T(x)` of Definition 8, relative
    /// to conflict class `class`: the operands whose access at `f` lands
    /// in that class.
    pub fn conflict_index_set(&self, f: &[i64], class: i64) -> Vec<usize> {
        (0..self.operands.len())
            .filter(|&p| self.class_at(p, f) == class)
            .collect()
    }

    /// Potential conflict level `|T(x)|` (Definition 8).
    pub fn conflict_level(&self, f: &[i64], class: i64) -> usize {
        self.conflict_index_set(f, class).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheSpec;
    use crate::domain::ops;
    use crate::domain::IterOrder;

    fn toy_spec() -> CacheSpec {
        // elem = 8B, line = 8B (element granularity), 4 sets, 2 ways:
        // capacity = 4*2*8 = 64B, period P = 64/(2*8) = 4 elements.
        CacheSpec::new(64, 8, 2, 1)
    }

    #[test]
    fn matmul_operand_lattices() {
        let k = ops::matmul(8, 8, 8, 8, 0);
        let ca = ConflictAnalysis::new(&k, &CacheSpec::HASWELL_L1D);
        // P = 32768/(8*8) = 512 elements
        assert_eq!(ca.period, 512);
        // A is 8x8 column-major: weights (1, 8); lattice det = 512
        assert_eq!(ca.operands[0].operand_lattice.det_abs(), 512);
        // loop weights for A[i,j] with lda=8: i + 8j → (1, 8, 0)
        assert_eq!(ca.operands[0].loop_weights, vec![1, 8, 0]);
    }

    #[test]
    fn class_matches_congruence_definition() {
        let k = ops::matmul(6, 5, 4, 8, 0);
        let ca = ConflictAnalysis::new(&k, &toy_spec());
        let order = IterOrder::lex(3);
        order.scan(k.extents(), |f| {
            for p in 0..3 {
                let e = ca.element_at(p, f);
                assert_eq!(ca.class_at(p, f), e.rem_euclid(ca.period));
                // membership in the loop lattice ⇔ class == class at origin
                if let Some(l) = &ca.operands[p].loop_lattice {
                    let f128: Vec<i128> = f.iter().map(|&x| x as i128).collect();
                    let origin_class = ca.class_at(p, &[0, 0, 0]);
                    if l.contains(&f128) {
                        assert_eq!(ca.class_at(p, f), origin_class);
                    }
                }
            }
        });
    }

    #[test]
    fn loop_lattice_matches_class_equality() {
        // Every loop point in Λ(A_p) touches the base class; points not in
        // Λ may still touch it only if the class repeats — the lattice
        // must capture exactly the f with w·f ≡ 0.
        let k = ops::matmul(8, 8, 8, 8, 0);
        let ca = ConflictAnalysis::new(&k, &toy_spec());
        let l = ca.operands[1].loop_lattice.as_ref().unwrap();
        IterOrder::lex(3).scan(k.extents(), |f| {
            let f128: Vec<i128> = f.iter().map(|&x| x as i128).collect();
            let w = &ca.operands[1].loop_weights;
            let dot: i64 = w.iter().zip(f).map(|(&a, &b)| a * b).sum();
            assert_eq!(
                l.contains(&f128),
                dot.rem_euclid(ca.period) == 0,
                "f={f:?}"
            );
        });
    }

    #[test]
    fn constant_access_has_no_loop_lattice() {
        let k = ops::scalar_product(16, 8, 0);
        let ca = ConflictAnalysis::new(&k, &toy_spec());
        // operand 0 is the scalar output A_0: constant access
        assert!(ca.operands[0].loop_lattice.is_none());
        // B and C are streamed: weights (1,)
        assert!(ca.operands[1].loop_lattice.is_some());
    }

    #[test]
    fn base_address_translates_classes() {
        // Same kernel, shifted base: classes shift by the base residue.
        let k0 = ops::matmul(4, 4, 4, 8, 0);
        let k1 = ops::matmul(4, 4, 4, 8, 2 * 8); // shift by 2 elements
        let c0 = ConflictAnalysis::new(&k0, &toy_spec());
        let c1 = ConflictAnalysis::new(&k1, &toy_spec());
        let f = [1i64, 2, 3];
        for p in 0..3 {
            assert_eq!(
                (c0.class_at(p, &f) + 2).rem_euclid(c0.period),
                c1.class_at(p, &f)
            );
        }
    }

    #[test]
    fn conflict_level_counts_operands() {
        // craft a point where A and B touch the same class
        let k = ops::matmul(4, 4, 4, 8, 0);
        let ca = ConflictAnalysis::new(&k, &toy_spec());
        let mut found_multi = false;
        IterOrder::lex(3).scan(k.extents(), |f| {
            for class in 0..ca.period {
                let lvl = ca.conflict_level(f, class);
                if lvl > 1 {
                    found_multi = true;
                }
                assert!(lvl <= 3);
            }
        });
        assert!(found_multi, "expected some cross-operand conflicts");
    }
}
