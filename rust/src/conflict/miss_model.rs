//! The actual-cache-miss model — Equations (1) and (4), §2.4 / §3.3.
//!
//! Walking the iteration domain in order `≺`, an operand's touch of element
//! `q` is a **reuse point** iff an earlier touch of the same `q` is close
//! enough that the intervening same-class loads cannot have evicted it;
//! otherwise it is a **miss point**. Eq. (1) sums miss points over the
//! conflict index-sets `T(x)`.
//!
//! Two closeness semantics are implemented:
//!
//! * [`Semantics::PaperDelta`] — the paper's literal rule: traversal
//!   distance `Δ_{Λ^D}(x, x') ≤ K`, counting *points* of `Λ^D` between the
//!   touches. Cheap, but an approximation: repeated touches of one element
//!   inflate the distance even though they occupy a single way.
//! * [`Semantics::StackDistance`] — count *distinct cachelines* of the
//!   class between the touches (classical stack distance restricted to
//!   the conflict class). This is provably identical to a K-way LRU set,
//!   which the keystone test verifies against the cache simulator exactly
//!   — including on the real Haswell spec with 8 elements per line.
//!
//! Granularity: both semantics operate on **cachelines** (the unit the
//! hardware moves); classes are the hardware's set indices. The paper's
//! Definition 7 works at element granularity (implicitly one element per
//! line); the lattice machinery in [`super::potential`] keeps that
//! element-granular form for tile construction, while the model here uses
//! lines so spatial locality is captured. The tiling optimizer uses
//! `StackDistance` (exact for LRU); benchmarks report both so the model
//! error of the paper's Δ rule is quantifiable (EXPERIMENTS.md).
//!
//! Cost: full evaluation is `O(|D|)` with a hash map — the paper notes it
//! is as expensive as running the code (§4.0.4). [`MissModel::sampled`]
//! implements the paper's remedy: evaluate a few conflict classes ("a few
//! certain sets") and scale.

use std::collections::{HashMap, HashSet};

use crate::cache::CacheSpec;
use crate::domain::order::Scanner;
use crate::domain::Kernel;

use super::potential::ConflictAnalysis;

/// Reuse-closeness semantics (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Semantics {
    PaperDelta,
    StackDistance,
}

/// Model outputs, split the way §2.4 discusses them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ModelCounts {
    /// Eq. (1)/(4) total: miss points summed over conflict index sets.
    pub misses: u64,
    /// First touches (the "cold" subset — Definition 9 notes their
    /// presence is inevitable; we report them separately).
    pub cold: u64,
    /// Reuse points (accesses classified `S_reuse`).
    pub reuses: u64,
    /// Misses per operand (the inner sum of Eq. (1) split by `p ∈ T(x)`).
    pub per_operand: Vec<u64>,
    /// Total loop points visited.
    pub points: u64,
}

impl ModelCounts {
    /// Non-cold misses — the conflict count the tiling optimizer minimizes.
    pub fn non_cold(&self) -> u64 {
        self.misses - self.cold
    }
}

/// The miss model for one kernel under one cache spec.
pub struct MissModel<'k> {
    kernel: &'k Kernel,
    analysis: ConflictAnalysis,
}

impl<'k> MissModel<'k> {
    pub fn new(kernel: &'k Kernel, spec: &CacheSpec) -> MissModel<'k> {
        MissModel {
            kernel,
            analysis: ConflictAnalysis::new(kernel, spec),
        }
    }

    pub fn analysis(&self) -> &ConflictAnalysis {
        &self.analysis
    }

    /// Exact evaluation over the whole domain in `order` with LRU-exact
    /// stack-distance semantics.
    pub fn exact(&self, order: &dyn Scanner) -> ModelCounts {
        self.run(order, None, Semantics::StackDistance)
    }

    /// Exact evaluation with the paper's literal Δ-distance rule (Eq. 1).
    pub fn exact_paper(&self, order: &dyn Scanner) -> ModelCounts {
        self.run(order, None, Semantics::PaperDelta)
    }

    /// Sampled evaluation (§4.0.4): track only the conflict classes in
    /// `classes`; counts are scaled by `period / classes.len()`.
    pub fn sampled(&self, order: &dyn Scanner, classes: &[i64]) -> ModelCounts {
        self.sampled_with(order, classes, Semantics::StackDistance)
    }

    pub fn sampled_with(
        &self,
        order: &dyn Scanner,
        classes: &[i64],
        sem: Semantics,
    ) -> ModelCounts {
        assert!(!classes.is_empty());
        let mut c = self.run(order, Some(classes), sem);
        let scale = self.analysis.n_classes as f64 / classes.len() as f64;
        let s = |v: u64| (v as f64 * scale).round() as u64;
        c.misses = s(c.misses);
        c.cold = s(c.cold);
        c.reuses = s(c.reuses);
        for m in c.per_operand.iter_mut() {
            *m = s(*m);
        }
        c
    }

    fn run(&self, order: &dyn Scanner, classes: Option<&[i64]>, sem: Semantics) -> ModelCounts {
        let n_ops = self.kernel.operands().len();
        let period = self.analysis.n_classes;
        let gran = self.analysis.gran;
        let ways = self.analysis.ways;

        let tracked: Option<Vec<bool>> = classes.map(|cs| {
            let mut v = vec![false; period as usize];
            for &c in cs {
                v[c.rem_euclid(period) as usize] = true;
            }
            v
        });

        let mut out = ModelCounts {
            per_operand: vec![0; n_ops],
            ..Default::default()
        };

        match sem {
            Semantics::StackDistance => {
                // Per class: LRU stack of the K most recent distinct
                // elements (MRU first) — exactly a K-way LRU set.
                let mut stacks: Vec<Vec<i64>> = vec![Vec::new(); period as usize];
                let mut seen: HashSet<i64> = HashSet::new();
                order.scan_points(self.kernel.extents(), &mut |f: &[i64]| {
                    out.points += 1;
                    for p in 0..n_ops {
                        let e = self.analysis.element_at(p, f).div_euclid(gran);
                        let rho = e.rem_euclid(period) as usize;
                        if let Some(t) = &tracked {
                            if !t[rho] {
                                continue;
                            }
                        }
                        let stack = &mut stacks[rho];
                        match stack.iter().position(|&x| x == e) {
                            Some(pos) => {
                                // resident iff among the K most recent
                                debug_assert!(pos < ways);
                                stack.remove(pos);
                                stack.insert(0, e);
                                out.reuses += 1;
                            }
                            None => {
                                out.misses += 1;
                                out.per_operand[p] += 1;
                                if seen.insert(e) {
                                    out.cold += 1;
                                }
                                stack.insert(0, e);
                                if stack.len() > ways {
                                    stack.pop();
                                }
                            }
                        }
                    }
                });
            }
            Semantics::PaperDelta => {
                // cnt[ρ] = number of Λ^D points seen in class ρ so far
                let mut cnt = vec![0u64; period as usize];
                // last touch of element e → cnt[class] right after it
                let mut last: HashMap<i64, u64> = HashMap::new();
                let mut touched: Vec<usize> = Vec::with_capacity(n_ops);
                order.scan_points(self.kernel.extents(), &mut |f: &[i64]| {
                    out.points += 1;
                    touched.clear();
                    for p in 0..n_ops {
                        let e = self.analysis.element_at(p, f).div_euclid(gran);
                        let rho = e.rem_euclid(period) as usize;
                        if let Some(t) = &tracked {
                            if !t[rho] {
                                continue;
                            }
                        }
                        let c_now = cnt[rho];
                        match last.get(&e) {
                            Some(&c_last) => {
                                // Δ = 1 + (# Λ^D points strictly between)
                                let delta = 1 + c_now - c_last;
                                if delta <= ways as u64 {
                                    out.reuses += 1;
                                } else {
                                    out.misses += 1;
                                    out.per_operand[p] += 1;
                                }
                            }
                            None => {
                                out.misses += 1;
                                out.cold += 1;
                                out.per_operand[p] += 1;
                            }
                        }
                        if !touched.contains(&rho) {
                            touched.push(rho);
                        }
                    }
                    // Λ^D is a set of points: one increment per class
                    for &rho in &touched {
                        cnt[rho] += 1;
                    }
                    for p in 0..n_ops {
                        let e = self.analysis.element_at(p, f).div_euclid(gran);
                        let rho = e.rem_euclid(period) as usize;
                        if let Some(t) = &tracked {
                            if !t[rho] {
                                continue;
                            }
                        }
                        last.insert(e, cnt[rho]);
                    }
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheSim, CacheSpec, Policy};
    use crate::domain::ops;
    use crate::domain::IterOrder;

    /// Element-granular cache spec matching the model's assumptions:
    /// line = elem (8B), so conflict classes coincide with cache sets.
    fn model_spec(period_elems: usize, ways: usize) -> CacheSpec {
        CacheSpec::new(period_elems * ways * 8, 8, ways, 1)
    }

    /// Keystone: stack-distance model == element-granular LRU simulation,
    /// exactly, for every op and ordering tried.
    fn check_model_equals_sim(kernel: &Kernel, spec: CacheSpec, order: &IterOrder) {
        let model = MissModel::new(kernel, &spec);
        let counts = model.exact(order);

        let mut sim = CacheSim::new(spec, Policy::Lru);
        order.scan(kernel.extents(), |f| {
            for a in kernel.addrs_at(f) {
                sim.access(a);
            }
        });
        assert_eq!(
            counts.misses,
            sim.stats().misses(),
            "model vs sim misses for {} {:?}",
            kernel.name(),
            order.perm()
        );
        assert_eq!(counts.cold, sim.stats().cold, "cold split");
    }

    use crate::domain::Kernel;

    #[test]
    fn model_equals_sim_matmul_all_orders() {
        let k = ops::matmul(6, 5, 7, 8, 0);
        let spec = model_spec(16, 2);
        for order in IterOrder::all(3) {
            check_model_equals_sim(&k, spec, &order);
        }
    }

    #[test]
    fn model_equals_sim_other_ops() {
        let spec = model_spec(8, 2);
        check_model_equals_sim(&ops::scalar_product(40, 8, 0), spec, &IterOrder::lex(1));
        check_model_equals_sim(&ops::convolution(40, 8, 0), spec, &IterOrder::lex(1));
        check_model_equals_sim(&ops::kronecker(3, 3, 4, 4, 8, 0), spec, &IterOrder::lex(4));
    }

    #[test]
    fn model_equals_sim_real_haswell_spec() {
        // The strongest form of the keystone: the line-granular model must
        // match the simulator on the real Haswell L1d spec (64B lines,
        // 8 elements per line, 8 ways, 64 sets) — spatial locality included.
        let k = ops::matmul(24, 20, 28, 8, 0);
        for order in [IterOrder::lex(3), IterOrder::permuted(&[1, 2, 0])] {
            check_model_equals_sim(&k, CacheSpec::HASWELL_L1D, &order);
        }
        // padded + offset too
        let k = ops::matmul_padded(20, 24, 16, 32, 40, 48, 8, 128);
        check_model_equals_sim(&k, CacheSpec::HASWELL_L1D, &IterOrder::lex(3));
    }

    #[test]
    fn model_equals_sim_tiled_schedule_haswell() {
        use crate::domain::order::Scanner;
        use crate::tiling::{TileBasis, TiledSchedule};
        let k = ops::matmul(32, 32, 32, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 16, 8]));
        let model = MissModel::new(&k, &CacheSpec::HASWELL_L1D);
        let counts = model.exact(&s);
        let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
        s.scan_points(k.extents(), &mut |f: &[i64]| {
            for a in k.addrs_at(f) {
                sim.access(a);
            }
        });
        assert_eq!(counts.misses, sim.stats().misses());
    }

    #[test]
    fn model_equals_sim_padded_and_offset() {
        let spec = model_spec(16, 4);
        let k = ops::matmul_padded(5, 6, 7, 8, 9, 16, 8, 24);
        for order in [IterOrder::lex(3), IterOrder::permuted(&[2, 0, 1])] {
            check_model_equals_sim(&k, spec, &order);
        }
    }

    #[test]
    fn paper_delta_is_close_but_not_exact() {
        // The literal Eq.(1) Δ rule approximates LRU: identical colds and
        // a total within a modest band on a thrashy matmul. (Δ can deviate
        // both ways: repeat touches of one element inflate the distance,
        // while several distinct elements sharing one loop point count as
        // a single Λ^D point and deflate it.)
        let k = ops::matmul(8, 8, 8, 8, 0);
        let spec = model_spec(16, 2);
        let model = MissModel::new(&k, &spec);
        let order = IterOrder::lex(3);
        let exact = model.exact(&order);
        let paper = model.exact_paper(&order);
        assert_eq!(exact.cold, paper.cold);
        let ratio = paper.misses as f64 / exact.misses as f64;
        assert!(
            (0.5..1.5).contains(&ratio),
            "Δ-rule off by {ratio:.2}x ({} vs {})",
            paper.misses,
            exact.misses
        );
    }

    #[test]
    fn paper_delta_ranks_orders_like_lru() {
        // For tile-selection purposes what matters is the *ranking* of
        // candidate orderings; verify Δ-rule and LRU-rule agree on which
        // of ijk vs jik is better here.
        let k = ops::matmul(12, 12, 12, 8, 0);
        let spec = model_spec(16, 2);
        let model = MissModel::new(&k, &spec);
        let orders = IterOrder::all(3);
        let exact: Vec<u64> = orders.iter().map(|o| model.exact(o).misses).collect();
        let paper: Vec<u64> = orders.iter().map(|o| model.exact_paper(o).misses).collect();
        let best_exact = exact.iter().enumerate().min_by_key(|(_, &v)| v).unwrap().0;
        let best_paper = paper.iter().enumerate().min_by_key(|(_, &v)| v).unwrap().0;
        assert_eq!(
            orders[best_exact].perm(),
            orders[best_paper].perm(),
            "Δ rule picked a different best ordering"
        );
    }

    #[test]
    fn ordering_changes_model_misses() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        let spec = model_spec(16, 2);
        let model = MissModel::new(&k, &spec);
        let m_ijk = model.exact(&IterOrder::lex(3)).misses;
        let m_kji = model.exact(&IterOrder::permuted(&[2, 1, 0])).misses;
        assert_ne!(m_ijk, m_kji);
    }

    #[test]
    fn sampled_estimates_within_tolerance() {
        let k = ops::matmul(12, 12, 12, 8, 0);
        let spec = model_spec(16, 2);
        let model = MissModel::new(&k, &spec);
        let order = IterOrder::lex(3);
        let exact = model.exact(&order);
        let classes: Vec<i64> = (0..16).step_by(2).collect();
        let est = model.sampled(&order, &classes);
        let rel = (est.misses as f64 - exact.misses as f64).abs() / exact.misses as f64;
        assert!(
            rel < 0.25,
            "sampled estimate off by {rel:.2} ({} vs {})",
            est.misses,
            exact.misses
        );
    }

    #[test]
    fn cold_misses_counted_once_per_element() {
        let k = ops::matmul(4, 4, 4, 8, 0);
        let spec = model_spec(64, 8); // big enough: everything fits
        let model = MissModel::new(&k, &spec);
        let c = model.exact(&IterOrder::lex(3));
        // distinct elements: A 16 + B 16 + C 16
        assert_eq!(c.cold, 48);
        assert_eq!(c.misses, 48, "no conflicts when the cache fits all");
    }

    #[test]
    fn per_operand_sums_to_total() {
        let k = ops::matmul(8, 8, 8, 8, 0);
        let spec = model_spec(16, 2);
        let c = MissModel::new(&k, &spec).exact(&IterOrder::lex(3));
        assert_eq!(c.per_operand.iter().sum::<u64>(), c.misses);
    }
}
