//! Associativity-conflict analysis — §2.3–§2.4 (DESIGN.md S5, S6).
//!
//! [`potential`] builds the conflict lattices `L(C, φ)` / `Λ(A_i)` and the
//! conflict index-sets `T(x)`; [`miss_model`] evaluates the actual-miss
//! Equations (1)/(4), exactly or by class-sampling.

pub mod miss_model;
pub mod potential;

pub use miss_model::{MissModel, ModelCounts};
pub use potential::{ConflictAnalysis, OperandConflicts};
