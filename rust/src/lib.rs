//! # latticetile
//!
//! A reproduction of *"Model-Driven Automatic Tiling with Cache Associativity
//! Lattices"* (Adjiashvili, Haus, Tate; cs.PF 2015) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper's thesis: conflict misses due to associativity are the only
//! fundamentally important cache-miss category; the set of potentially
//! conflicting addresses of an operand under an affine index map forms an
//! integer **lattice** `L(C, φ)`; and tiles shaped as fundamental
//! parallelepipeds of that lattice (rather than rectangles) have constant
//! per-tile miss counts and maximal volume.
//!
//! ## Crate layout (bottom-up)
//!
//! * [`lattice`] — exact integer-lattice machinery (HNF, LLL, determinants);
//!   the paper used NTL.
//! * [`cache`] — K-way set-associative cache simulator with LRU/PLRU
//!   eviction; the paper measured a Haswell L1d.
//! * [`index`] — affine index maps `φ` (§2.1.1) for `d`-dimensional tables.
//! * [`domain`] — iteration domains, the Table-1 operations, reuse domains,
//!   and iteration orderings (§2.1.2–§2.2).
//! * [`conflict`] — potential-conflict lattices `L(C,φ)` (§2.3) and the
//!   actual-cache-miss model, Eq. (1)/(4) (§2.4, §3.3).
//! * [`tiling`] — tiling mechanics `P_D(H)`, `T_D(H)`, `r(x)` (§3.2) and
//!   tile selection (the `K−1` lattice-point rule and model-driven search,
//!   §4.0.4).
//! * [`codegen`] — loop-nest schedule generation (the paper used CLooG) and
//!   the instrumented tiled-matmul executor, including the parallel
//!   (auto-threading) executor (§4.0.3).
//! * [`baseline`] — compiler-analog scheduling strategies (gcc −O0/−O2/−O3,
//!   graphite, icc, pgi) and the reference GEMM oracle.
//! * [`runtime`] — PJRT artifact registry: loads the AOT-compiled JAX/Pallas
//!   HLO-text artifacts and executes them from the Rust hot path.
//! * [`coordinator`] — the L3 service: job queue, planner, batcher, metrics.
//! * [`experiments`] — one module per paper table/figure (DESIGN.md §2),
//!   shared by `benches/` and the CLI.
//! * [`testutil`] — deterministic property-testing support.

pub mod baseline;
pub mod cache;
pub mod codegen;
pub mod conflict;
pub mod coordinator;
pub mod domain;
pub mod experiments;
pub mod index;
pub mod lattice;
pub mod runtime;
pub mod testutil;
pub mod tiling;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
