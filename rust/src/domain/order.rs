//! Iteration orderings `≺` — Definitions 4–6.
//!
//! A total order on the loop space. We implement the permuted-lexicographic
//! family (all `d!` loop interchanges) which, combined with tiling
//! (`tiling::schedule`), spans the schedules the paper's framework emits.

/// Anything that can traverse the integer box `[0, extents_i)` in a
/// well-defined total order: plain loop nests ([`IterOrder`]) and tiled
/// schedules ([`crate::tiling::TiledSchedule`]). The miss model and the
/// executors are generic over this, so the same Eq.(1)/(4) machinery
/// scores untiled and tiled codes (§3.3).
pub trait Scanner {
    fn scan_points(&self, extents: &[i64], f: &mut dyn FnMut(&[i64]));
}

impl Scanner for IterOrder {
    fn scan_points(&self, extents: &[i64], f: &mut dyn FnMut(&[i64])) {
        self.scan(extents, f);
    }
}

/// Permuted lexicographic order: compare loop points by the variables in
/// `perm[0]` (outermost / most significant) first.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IterOrder {
    perm: Vec<usize>,
}

impl IterOrder {
    pub fn lex(n: usize) -> IterOrder {
        IterOrder {
            perm: (0..n).collect(),
        }
    }

    /// `perm[0]` is the outermost loop.
    pub fn permuted(perm: &[usize]) -> IterOrder {
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            assert!(p < perm.len() && !seen[p], "invalid permutation");
            seen[p] = true;
        }
        IterOrder {
            perm: perm.to_vec(),
        }
    }

    pub fn n(&self) -> usize {
        self.perm.len()
    }

    pub fn perm(&self) -> &[usize] {
        &self.perm
    }

    /// Strict comparison `a ≺ b`.
    pub fn before(&self, a: &[i64], b: &[i64]) -> bool {
        for &v in &self.perm {
            match a[v].cmp(&b[v]) {
                std::cmp::Ordering::Less => return true,
                std::cmp::Ordering::Greater => return false,
                std::cmp::Ordering::Equal => {}
            }
        }
        false
    }

    /// Enumerate the box `[0, extents_i)` in this order, calling `f` on
    /// each point. The workhorse of the exact miss model — a hand-rolled
    /// odometer to avoid per-point allocation.
    pub fn scan<F: FnMut(&[i64])>(&self, extents: &[i64], mut f: F) {
        assert_eq!(extents.len(), self.perm.len());
        if extents.iter().any(|&e| e <= 0) {
            return;
        }
        let n = extents.len();
        let mut p = vec![0i64; n];
        loop {
            f(&p);
            // increment innermost-first (reverse of perm)
            let mut lvl = n;
            loop {
                if lvl == 0 {
                    return;
                }
                lvl -= 1;
                let v = self.perm[lvl];
                p[v] += 1;
                if p[v] < extents[v] {
                    break;
                }
                p[v] = 0;
            }
        }
    }

    /// All `n!` permutations of `n` loops (the paper's small search space
    /// of orderings).
    pub fn all(n: usize) -> Vec<IterOrder> {
        let mut out = Vec::new();
        let mut perm: Vec<usize> = (0..n).collect();
        permute(&mut perm, 0, &mut out);
        out
    }
}

fn permute(perm: &mut Vec<usize>, k: usize, out: &mut Vec<IterOrder>) {
    if k == perm.len() {
        out.push(IterOrder::permuted(perm));
        return;
    }
    for i in k..perm.len() {
        perm.swap(k, i);
        permute(perm, k + 1, out);
        perm.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lex_scan_order() {
        let o = IterOrder::lex(2);
        let mut pts = Vec::new();
        o.scan(&[2, 3], |p| pts.push(p.to_vec()));
        assert_eq!(
            pts,
            vec![
                vec![0, 0],
                vec![0, 1],
                vec![0, 2],
                vec![1, 0],
                vec![1, 1],
                vec![1, 2]
            ]
        );
        // consistency with before()
        for w in pts.windows(2) {
            assert!(o.before(&w[0], &w[1]));
            assert!(!o.before(&w[1], &w[0]));
        }
    }

    #[test]
    fn permuted_scan_order() {
        // j outermost
        let o = IterOrder::permuted(&[1, 0]);
        let mut pts = Vec::new();
        o.scan(&[2, 2], |p| pts.push(p.to_vec()));
        assert_eq!(
            pts,
            vec![vec![0, 0], vec![1, 0], vec![0, 1], vec![1, 1]]
        );
    }

    #[test]
    fn all_permutations_count() {
        assert_eq!(IterOrder::all(3).len(), 6);
        assert_eq!(IterOrder::all(4).len(), 24);
        // all distinct
        let set: std::collections::HashSet<Vec<usize>> =
            IterOrder::all(3).iter().map(|o| o.perm.clone()).collect();
        assert_eq!(set.len(), 6);
    }

    #[test]
    fn empty_extent_scans_nothing() {
        let o = IterOrder::lex(2);
        let mut n = 0;
        o.scan(&[0, 5], |_| n += 1);
        assert_eq!(n, 0);
    }

    #[test]
    fn before_is_total_order() {
        let o = IterOrder::permuted(&[2, 0, 1]);
        let a = [1i64, 2, 3];
        let b = [2i64, 1, 3];
        // compare by var2 (eq), then var0: a < b
        assert!(o.before(&a, &b));
        assert!(!o.before(&b, &a));
        assert!(!o.before(&a, &a));
    }
}
