//! Iteration domains, operands, orderings, reuse — §2.1–§2.2 (DESIGN.md S4).
//!
//! Two equivalent formulations of a computation:
//! * the paper's product-space view — [`joint::JointDomain`] = joint index
//!   set `Q(A_1,…,A_k)` ∩ affine constraint set `H` (Definition 2, Table 1);
//! * the loop-space view — [`kernel::Kernel`] = free loop variables plus
//!   per-operand affine access functions (`π_i` restricted to `H`).
//!
//! `joint::tests` proves them equivalent on every Table-1 op; everything
//! downstream (conflict analysis, tiling, codegen) uses the loop-space view.

pub mod access;
pub mod joint;
pub mod kernel;
pub mod ops;
pub mod order;
pub mod reuse;

pub use access::AffineAccess;
pub use joint::{Constraint, JointDomain};
pub use kernel::{Kernel, OpRole, Operand};
pub use order::IterOrder;
