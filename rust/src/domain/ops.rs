//! The operations of the paper's Table 1: scalar product, convolution,
//! matrix multiplication, Kronecker product — each as a [`Kernel`] with
//! affine access functions, and (in [`crate::domain::joint`]) as a joint
//! iteration domain with the table's constraint set `H`.

use super::access::AffineAccess;
use super::kernel::{Kernel, OpRole, Operand};
use crate::index::{IndexMap, Layout, Table};

/// Scalar product `A_0 = Σ_k B_k C_k` (Table 1, row 1).
///
/// Free variable: `k ∈ [0, n)`. Constraint set `{i_1 = 0, i_2 = i_3}`.
pub fn scalar_product(n: i64, elem: usize, base: usize) -> Kernel {
    let a = Table::new("A", &[1], Layout::ColumnMajor, elem, base);
    let b = Table::new(
        "B",
        &[n],
        Layout::ColumnMajor,
        elem,
        base + elem,
    );
    let c = Table::new(
        "C",
        &[n],
        Layout::ColumnMajor,
        elem,
        base + elem * (1 + n as usize),
    );
    Kernel::new(
        "scalar_product",
        vec![n],
        vec![
            Operand {
                table: a,
                access: AffineAccess::constant(1, &[0]),
                role: OpRole::ReadWrite,
            },
            Operand {
                table: b,
                access: AffineAccess::select(1, &[0]),
                role: OpRole::Read,
            },
            Operand {
                table: c,
                access: AffineAccess::select(1, &[0]),
                role: OpRole::Read,
            },
        ],
    )
}

/// Convolution `A_0 = Σ_k B_k C_{m^C − k − 1}` (Table 1, row 2).
///
/// Constraint set `{i_1 = 0, i_2 = m_1^C − i_3}` (with the paper's
/// off-by-one made explicit: the reversed index is `m^C − 1 − k`).
pub fn convolution(n: i64, elem: usize, base: usize) -> Kernel {
    let a = Table::new("A", &[1], Layout::ColumnMajor, elem, base);
    let b = Table::new("B", &[n], Layout::ColumnMajor, elem, base + elem);
    let c = Table::new(
        "C",
        &[n],
        Layout::ColumnMajor,
        elem,
        base + elem * (1 + n as usize),
    );
    Kernel::new(
        "convolution",
        vec![n],
        vec![
            Operand {
                table: a,
                access: AffineAccess::constant(1, &[0]),
                role: OpRole::ReadWrite,
            },
            Operand {
                table: b,
                access: AffineAccess::select(1, &[0]),
                role: OpRole::Read,
            },
            Operand {
                table: c,
                // C_{n-1-k}
                access: AffineAccess::new(vec![vec![-1]], vec![n - 1]),
                role: OpRole::Read,
            },
        ],
    )
}

/// Matrix multiplication `A_{i,j} = Σ_k B_{i,k} C_{k,j}` (Table 1, row 3):
/// `B` is `m×k`, `C` is `k×n`, `A` is `m×n`. Column-major, packed
/// `A | B | C` starting at `base`. Free variables `(i, j, kk)`.
pub fn matmul(m: i64, k: i64, n: i64, elem: usize, base: usize) -> Kernel {
    matmul_padded(m, k, n, m, m, k, elem, base)
}

/// Matmul with padded leading dimensions (`lda`, `ldb`, `ldc` in BLAS
/// terms, all column-major): padding the leading dimension is the paper's
/// classic lever for detuning/retuning the conflict lattice.
#[allow(clippy::too_many_arguments)]
pub fn matmul_padded(
    m: i64,
    k: i64,
    n: i64,
    lda: i64, // physical rows of A (≥ m)
    ldb: i64, // physical rows of B (≥ m)
    ldc: i64, // physical rows of C (≥ k)
    elem: usize,
    base: usize,
) -> Kernel {
    assert!(lda >= m && ldb >= m && ldc >= k);
    let a_map = IndexMap::padded(&[m, n], &[lda, n], Layout::ColumnMajor);
    let b_map = IndexMap::padded(&[m, k], &[ldb, k], Layout::ColumnMajor);
    let c_map = IndexMap::padded(&[k, n], &[ldc, n], Layout::ColumnMajor);
    let a_bytes = (lda * n) as usize * elem;
    let b_bytes = (ldb * k) as usize * elem;
    let a = Table::with_map("A", a_map, elem, base);
    let b = Table::with_map("B", b_map, elem, base + a_bytes);
    let c = Table::with_map("C", c_map, elem, base + a_bytes + b_bytes);
    Kernel::new(
        "matmul",
        vec![m, n, k],
        vec![
            Operand {
                table: a,
                access: AffineAccess::select(3, &[0, 1]), // A[i,j]
                role: OpRole::ReadWrite,
            },
            Operand {
                table: b,
                access: AffineAccess::select(3, &[0, 2]), // B[i,kk]
                role: OpRole::Read,
            },
            Operand {
                table: c,
                access: AffineAccess::select(3, &[2, 1]), // C[kk,j]
                role: OpRole::Read,
            },
        ],
    )
}

/// Kronecker product
/// `A_{m_1^C·i + k, m_2^C·j + l} = B_{i,j} · C_{k,l}` (Table 1, row 4).
/// Free variables `(i, j, k, l)`; `B` is `m1B×m2B`, `C` is `m1C×m2C`,
/// `A` is `(m1B·m1C)×(m2B·m2C)`.
pub fn kronecker(m1b: i64, m2b: i64, m1c: i64, m2c: i64, elem: usize, base: usize) -> Kernel {
    let a_dims = [m1b * m1c, m2b * m2c];
    let a = Table::new("A", &a_dims, Layout::ColumnMajor, elem, base);
    let a_bytes = (a_dims[0] * a_dims[1]) as usize * elem;
    let b = Table::new("B", &[m1b, m2b], Layout::ColumnMajor, elem, base + a_bytes);
    let b_bytes = (m1b * m2b) as usize * elem;
    let c = Table::new(
        "C",
        &[m1c, m2c],
        Layout::ColumnMajor,
        elem,
        base + a_bytes + b_bytes,
    );
    Kernel::new(
        "kronecker",
        vec![m1b, m2b, m1c, m2c],
        vec![
            Operand {
                table: a,
                // A[m1c*i + k, m2c*j + l]
                access: AffineAccess::new(
                    vec![vec![m1c, 0, 1, 0], vec![0, m2c, 0, 1]],
                    vec![0, 0],
                ),
                role: OpRole::Write,
            },
            Operand {
                table: b,
                access: AffineAccess::select(4, &[0, 1]),
                role: OpRole::Read,
            },
            Operand {
                table: c,
                access: AffineAccess::select(4, &[2, 3]),
                role: OpRole::Read,
            },
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::order::IterOrder;

    #[test]
    fn all_table1_ops_in_bounds() {
        scalar_product(17, 8, 0).validate_bounds().unwrap();
        convolution(17, 8, 64).validate_bounds().unwrap();
        matmul(5, 6, 7, 8, 0).validate_bounds().unwrap();
        matmul_padded(5, 6, 7, 9, 8, 11, 8, 128)
            .validate_bounds()
            .unwrap();
        kronecker(3, 4, 5, 2, 8, 0).validate_bounds().unwrap();
    }

    #[test]
    fn kronecker_covers_output_exactly_once() {
        let k = kronecker(2, 3, 4, 5, 8, 0);
        let out = &k.operands()[0];
        let mut seen = std::collections::HashSet::new();
        IterOrder::lex(4).scan(k.extents(), |f| {
            let x = out.access.apply(f);
            assert!(seen.insert(x), "output index written twice");
        });
        assert_eq!(seen.len() as i64, 2 * 3 * 4 * 5);
    }

    #[test]
    fn convolution_reverses() {
        let k = convolution(10, 8, 0);
        let c = &k.operands()[2];
        assert_eq!(c.access.apply(&[0]), vec![9]);
        assert_eq!(c.access.apply(&[9]), vec![0]);
    }

    #[test]
    fn matmul_operands_disjoint_in_memory() {
        let k = matmul(8, 8, 8, 8, 0);
        let spans: Vec<(usize, usize)> = k
            .operands()
            .iter()
            .map(|o| (o.table.base(), o.table.base() + o.table.bytes()))
            .collect();
        for i in 0..spans.len() {
            for j in i + 1..spans.len() {
                assert!(
                    spans[i].1 <= spans[j].0 || spans[j].1 <= spans[i].0,
                    "operands {i} and {j} overlap"
                );
            }
        }
    }
}
