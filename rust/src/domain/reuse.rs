//! Reuse domains — Definition 3.
//!
//! `R_i(q) = {x ∈ D : π_i(x) = q}`: every iteration touching a fixed data
//! element `q` of operand `A_i`. The paper replaces the classical 1-D
//! "reuse vector" with this set because high-dimensional domains reuse a
//! datum along a whole affine subspace (e.g. matmul reuses `B[i,k]` for
//! every `j`).

use super::kernel::Kernel;
use super::order::IterOrder;

/// Enumerate the reuse domain of element `q` of operand `op_idx`
/// (exhaustive scan — test/model use; the production miss model tracks
/// reuse incrementally instead).
pub fn reuse_domain(kernel: &Kernel, op_idx: usize, q: &[i64]) -> Vec<Vec<i64>> {
    let op = kernel.operand(op_idx);
    let mut out = Vec::new();
    IterOrder::lex(kernel.n_free()).scan(kernel.extents(), |f| {
        if op.access.apply(f) == q {
            out.push(f.to_vec());
        }
    });
    out
}

/// The *subsequent reuse* of a point (Definition 5): the ≺-least point of
/// the same reuse domain strictly after `x`, if any.
pub fn subsequent_reuse(
    kernel: &Kernel,
    op_idx: usize,
    order: &IterOrder,
    x: &[i64],
) -> Option<Vec<i64>> {
    let q = kernel.operand(op_idx).access.apply(x);
    reuse_domain(kernel, op_idx, &q)
        .into_iter()
        .filter(|y| order.before(x, y))
        .min_by(|a, b| {
            if order.before(a, b) {
                std::cmp::Ordering::Less
            } else if order.before(b, a) {
                std::cmp::Ordering::Greater
            } else {
                std::cmp::Ordering::Equal
            }
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::ops;

    #[test]
    fn matmul_b_reuse_is_j_fiber() {
        // B[i,kk] is reused for every j: |R| = n
        let k = ops::matmul(3, 4, 5, 8, 0);
        let r = reuse_domain(&k, 1, &[1, 2]);
        assert_eq!(r.len(), 5);
        for f in &r {
            assert_eq!(f[0], 1); // i fixed
            assert_eq!(f[2], 2); // kk fixed
        }
    }

    #[test]
    fn matmul_a_reuse_is_k_fiber() {
        let k = ops::matmul(3, 4, 5, 8, 0);
        let r = reuse_domain(&k, 0, &[0, 0]);
        assert_eq!(r.len(), 4); // one per kk
    }

    #[test]
    fn scalar_output_reused_everywhere() {
        let k = ops::scalar_product(9, 8, 0);
        let r = reuse_domain(&k, 0, &[0]);
        assert_eq!(r.len(), 9);
    }

    #[test]
    fn subsequent_reuse_lex() {
        let k = ops::matmul(2, 3, 2, 8, 0);
        let order = IterOrder::lex(3);
        // A[0,0] touched at (0,0,kk) for kk in 0..3; from (0,0,0) next is
        // (0,0,1)
        let next = subsequent_reuse(&k, 0, &order, &[0, 0, 0]).unwrap();
        assert_eq!(next, vec![0, 0, 1]);
        // from the last one, none
        assert!(subsequent_reuse(&k, 0, &order, &[0, 0, 2]).is_none());
    }

    #[test]
    fn subsequent_reuse_respects_order() {
        let k = ops::matmul(2, 2, 2, 8, 0);
        // with j outermost, B[i,kk]'s reuses are adjacent in j
        let order = IterOrder::permuted(&[1, 0, 2]);
        let next = subsequent_reuse(&k, 1, &order, &[0, 0, 0]).unwrap();
        assert_eq!(next, vec![0, 1, 0]);
    }
}
