//! A computation kernel: free loop variables + operands + access functions.
//!
//! This is the loop-space view of the paper's joint iteration domain
//! `Q(A_1,…,A_k) ∩ H` (see [`crate::domain::joint`] for the product-space
//! view and the proof-by-test that they coincide).

use super::access::AffineAccess;
use crate::index::Table;

/// Role of an operand in the computation (read/write matters for write
/// policies; the miss model treats both as cache touches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpRole {
    Read,
    Write,
    ReadWrite,
}

/// One operand slot of a kernel.
#[derive(Clone, Debug)]
pub struct Operand {
    pub table: Table,
    pub access: AffineAccess,
    pub role: OpRole,
}

/// A kernel = loop extents + operands with affine accesses.
#[derive(Clone, Debug)]
pub struct Kernel {
    name: String,
    /// Extents of the free loop variables (iteration domain is the box
    /// `[0, extents_i)` — all Table-1 ops have box-shaped free domains).
    extents: Vec<i64>,
    operands: Vec<Operand>,
}

impl Kernel {
    pub fn new(name: &str, extents: Vec<i64>, operands: Vec<Operand>) -> Kernel {
        for op in &operands {
            assert_eq!(op.access.n_free(), extents.len(), "access arity mismatch");
            assert_eq!(op.access.rank(), op.table.rank(), "access rank mismatch");
        }
        Kernel {
            name: name.to_string(),
            extents,
            operands,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn extents(&self) -> &[i64] {
        &self.extents
    }

    pub fn n_free(&self) -> usize {
        self.extents.len()
    }

    pub fn operands(&self) -> &[Operand] {
        &self.operands
    }

    pub fn operand(&self, i: usize) -> &Operand {
        &self.operands[i]
    }

    /// Total points in the free iteration domain.
    pub fn domain_size(&self) -> i64 {
        self.extents.iter().product()
    }

    /// Byte addresses touched by one loop point, in operand order.
    pub fn addrs_at(&self, f: &[i64]) -> Vec<usize> {
        self.operands
            .iter()
            .map(|op| {
                let x = op.access.apply(f);
                op.table.addr(&x)
            })
            .collect()
    }

    /// Verify all accesses stay inside their tables over the whole domain
    /// (exhaustive — test/validation use only).
    pub fn validate_bounds(&self) -> anyhow::Result<()> {
        let order = super::order::IterOrder::lex(self.n_free());
        let mut ok = true;
        order.scan(&self.extents, |f| {
            for op in &self.operands {
                let x = op.access.apply(f);
                if !op.table.map().in_bounds(&x) {
                    ok = false;
                }
            }
        });
        anyhow::ensure!(ok, "kernel {} has out-of-bounds accesses", self.name);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::domain::ops;

    #[test]
    fn matmul_kernel_shape() {
        let k = ops::matmul(4, 5, 6, 8, 0);
        assert_eq!(k.extents(), &[4, 6, 5]); // (i, j, k)
        assert_eq!(k.operands().len(), 3);
        k.validate_bounds().unwrap();
    }

    #[test]
    fn matmul_addrs() {
        let k = ops::matmul(2, 3, 2, 8, 0);
        // f = (i=1, j=0, kk=2): A[1,2], B[2,0], C[1,0]
        let addrs = k.addrs_at(&[1, 0, 2]);
        assert_eq!(addrs.len(), 3);
    }
}
