//! Affine access functions: the projections `π_i` of §2.1.2 expressed on
//! the free loop variables.
//!
//! The paper formulates computations as a joint index set
//! `Q(A_1)×…×Q(A_k)` intersected with an affine subspace `H`. Operationally
//! (and equivalently, see `domain::joint`), a computation is a loop nest
//! over free variables `f ∈ Z^n` plus, per operand, an affine map
//! `f ↦ access_i(f) ∈ Q(A_i)`. This is the polyhedral "access function" the
//! paper borrows (§2.3).

/// An affine map `Z^n_free → Z^rank`: `x = M·f + c`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AffineAccess {
    /// `rank × n_free` coefficient rows.
    pub coef: Vec<Vec<i64>>,
    /// Constant term per output dimension.
    pub cons: Vec<i64>,
}

impl AffineAccess {
    pub fn new(coef: Vec<Vec<i64>>, cons: Vec<i64>) -> AffineAccess {
        assert_eq!(coef.len(), cons.len());
        AffineAccess { coef, cons }
    }

    /// Identity on a subset of loop vars: output dim `r` reads loop var
    /// `vars[r]`.
    pub fn select(n_free: usize, vars: &[usize]) -> AffineAccess {
        let coef = vars
            .iter()
            .map(|&v| {
                let mut row = vec![0i64; n_free];
                row[v] = 1;
                row
            })
            .collect();
        AffineAccess {
            coef,
            cons: vec![0; vars.len()],
        }
    }

    /// Constant access (e.g. the scalar output `A_0`).
    pub fn constant(n_free: usize, point: &[i64]) -> AffineAccess {
        AffineAccess {
            coef: vec![vec![0; n_free]; point.len()],
            cons: point.to_vec(),
        }
    }

    pub fn rank(&self) -> usize {
        self.cons.len()
    }

    pub fn n_free(&self) -> usize {
        self.coef.first().map_or(0, |r| r.len())
    }

    /// `access(f)`.
    pub fn apply(&self, f: &[i64]) -> Vec<i64> {
        self.coef
            .iter()
            .zip(&self.cons)
            .map(|(row, &c)| c + row.iter().zip(f).map(|(&a, &x)| a * x).sum::<i64>())
            .collect()
    }

    /// Apply into a preallocated buffer (hot path of the miss model).
    pub fn apply_into(&self, f: &[i64], out: &mut [i64]) {
        for (o, (row, &c)) in out.iter_mut().zip(self.coef.iter().zip(&self.cons)) {
            *o = c + row.iter().zip(f).map(|(&a, &x)| a * x).sum::<i64>();
        }
    }

    /// The composed linear weights of `φ ∘ access` on the loop variables:
    /// if `φ(x) = Σ w_r x_r + o` then
    /// `φ(access(f)) = Σ_j (Σ_r w_r M_{r,j}) f_j + (o + Σ w_r c_r)`.
    ///
    /// These composed weights are what generate the *iteration-space*
    /// conflict lattice `Λ(A_i)` directly (§2.4).
    pub fn compose_weights(&self, phi_weights: &[i64], phi_offset: i64) -> (Vec<i64>, i64) {
        assert_eq!(phi_weights.len(), self.rank());
        let n = self.n_free();
        let mut w = vec![0i64; n];
        for j in 0..n {
            for r in 0..self.rank() {
                w[j] += phi_weights[r] * self.coef[r][j];
            }
        }
        let o = phi_offset
            + phi_weights
                .iter()
                .zip(&self.cons)
                .map(|(&wr, &cr)| wr * cr)
                .sum::<i64>();
        (w, o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_and_apply() {
        // matmul B[i,k]: free = (i, j, k) → select [0, 2]
        let a = AffineAccess::select(3, &[0, 2]);
        assert_eq!(a.apply(&[4, 5, 6]), vec![4, 6]);
    }

    #[test]
    fn constant_access() {
        let a = AffineAccess::constant(2, &[0]);
        assert_eq!(a.apply(&[9, 9]), vec![0]);
    }

    #[test]
    fn convolution_access() {
        // C_{m-1-k}: coef -1 on k, const m-1 (m = 10)
        let a = AffineAccess::new(vec![vec![-1]], vec![9]);
        assert_eq!(a.apply(&[0]), vec![9]);
        assert_eq!(a.apply(&[9]), vec![0]);
    }

    #[test]
    fn compose_weights_matches_pointwise() {
        // φ(x) = x1 + 8 x2 + 3; access f=(i,j,k) → (i, k)
        let a = AffineAccess::select(3, &[0, 2]);
        let (w, o) = a.compose_weights(&[1, 8], 3);
        for f in [[0i64, 0, 0], [1, 2, 3], [5, 0, 7]] {
            let x = a.apply(&f);
            let direct = x[0] + 8 * x[1] + 3;
            let composed = o + w.iter().zip(&f).map(|(&wi, &fi)| wi * fi).sum::<i64>();
            assert_eq!(direct, composed);
        }
    }
}
