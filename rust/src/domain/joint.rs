//! The paper's product-space view: joint index sets and affine constraint
//! sets `H` — Definition 2 and Table 1.
//!
//! `Q(A_1,…,A_k) = Q(A_1) × ⋯ × Q(A_k)` intersected with an affine
//! subspace `H` given by integer equality constraints. This module exists
//! to state the paper's formalism *literally* and to verify (by exhaustive
//! test) that the loop-space [`Kernel`](super::kernel::Kernel) view
//! enumerates exactly the same set — so everything downstream can use the
//! cheaper loop-space form.

use super::kernel::Kernel;
use super::order::IterOrder;

/// One affine equality over the joint coordinates: `Σ a_i x_i = b`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Constraint {
    pub coef: Vec<i64>,
    pub rhs: i64,
}

impl Constraint {
    /// `x[i] = x[j]`.
    pub fn equal(n: usize, i: usize, j: usize) -> Constraint {
        let mut coef = vec![0; n];
        coef[i] = 1;
        coef[j] = -1;
        Constraint { coef, rhs: 0 }
    }

    /// `x[i] = c`.
    pub fn fixed(n: usize, i: usize, c: i64) -> Constraint {
        let mut coef = vec![0; n];
        coef[i] = 1;
        Constraint { coef, rhs: c }
    }

    pub fn satisfied(&self, x: &[i64]) -> bool {
        self.coef.iter().zip(x).map(|(&a, &v)| a * v).sum::<i64>() == self.rhs
    }
}

/// A joint iteration domain: per-operand index-set extents (concatenated)
/// plus the constraint set `H`.
#[derive(Clone, Debug)]
pub struct JointDomain {
    /// Extents of the concatenated coordinates, operand by operand.
    pub extents: Vec<i64>,
    /// Start offset of each operand's coordinate block.
    pub block_starts: Vec<usize>,
    pub constraints: Vec<Constraint>,
}

impl JointDomain {
    /// The projection `π_i` — slice out operand `i`'s block.
    pub fn project<'a>(&self, i: usize, x: &'a [i64]) -> &'a [i64] {
        let s = self.block_starts[i];
        let e = self
            .block_starts
            .get(i + 1)
            .copied()
            .unwrap_or(self.extents.len());
        &x[s..e]
    }

    pub fn contains(&self, x: &[i64]) -> bool {
        x.len() == self.extents.len()
            && x.iter().zip(&self.extents).all(|(&v, &m)| v >= 0 && v < m)
            && self.constraints.iter().all(|c| c.satisfied(x))
    }

    /// Exhaustively enumerate `Q ∩ H` (small domains only — tests).
    pub fn enumerate(&self) -> Vec<Vec<i64>> {
        let mut out = Vec::new();
        IterOrder::lex(self.extents.len()).scan(&self.extents, |p| {
            if self.constraints.iter().all(|c| c.satisfied(p)) {
                out.push(p.to_vec());
            }
        });
        out
    }

    /// Build the joint domain corresponding to a [`Kernel`]: coordinates
    /// are the concatenated operand indices; `H` is derived from the access
    /// functions by eliminating the free variables (valid for the Table-1
    /// ops whose accesses jointly determine `f`).
    ///
    /// Construction: for each pair of (operand dim, operand dim) reading
    /// the same single free variable with coefficient ±1, emit an equality;
    /// for constant accesses emit fixed constraints; for compound rows
    /// (Kronecker) emit the linear relation between blocks.
    pub fn of_kernel(kernel: &Kernel) -> JointDomain {
        let mut extents = Vec::new();
        let mut block_starts = Vec::new();
        for op in kernel.operands() {
            block_starts.push(extents.len());
            extents.extend_from_slice(op.table.dims());
        }
        let n = extents.len();

        // Collect, per output coordinate, its affine row over free vars.
        struct Row {
            joint_idx: usize,
            coef: Vec<i64>,
            cons: i64,
        }
        let mut rows: Vec<Row> = Vec::new();
        {
            let mut ji = 0usize;
            for op in kernel.operands() {
                for r in 0..op.access.rank() {
                    rows.push(Row {
                        joint_idx: ji,
                        coef: op.access.coef[r].clone(),
                        cons: op.access.cons[r],
                    });
                    ji += 1;
                }
            }
        }

        let mut constraints = Vec::new();
        // Constant rows: x = c.
        for row in &rows {
            if row.coef.iter().all(|&a| a == 0) {
                constraints.push(Constraint::fixed(n, row.joint_idx, row.cons));
            }
        }
        // For every free variable, find a "pivot" row that reads exactly
        // that variable with coefficient 1 and constant 0 (all Table-1 ops
        // have one); then express every other row mentioning the variable
        // against the pivot.
        let n_free = kernel.n_free();
        for v in 0..n_free {
            let pivot = rows.iter().find(|r| {
                r.cons == 0
                    && r.coef[v] == 1
                    && r.coef.iter().enumerate().all(|(j, &a)| j == v || a == 0)
            });
            let Some(p) = pivot else { continue };
            for r in &rows {
                if std::ptr::eq(r, p) || r.coef[v] == 0 {
                    continue;
                }
                // x_r = Σ_w a_w f_w + c. Substitute every f_w by its pivot
                // coordinate (requires each w to have a pivot — true for
                // Table-1). Emit only once: when v is the smallest var in r.
                if (0..v).any(|w| r.coef[w] != 0) {
                    continue;
                }
                let mut coef = vec![0i64; n];
                coef[r.joint_idx] = 1;
                let mut ok = true;
                for (w, &a) in r.coef.iter().enumerate() {
                    if a == 0 {
                        continue;
                    }
                    let pw = rows.iter().find(|rr| {
                        rr.cons == 0
                            && rr.coef[w] == 1
                            && rr.coef.iter().enumerate().all(|(j, &b)| j == w || b == 0)
                    });
                    match pw {
                        Some(pw) if pw.joint_idx != r.joint_idx => {
                            coef[pw.joint_idx] -= a;
                        }
                        _ => ok = false,
                    }
                }
                if ok {
                    constraints.push(Constraint {
                        coef,
                        rhs: r.cons,
                    });
                }
            }
        }

        JointDomain {
            extents,
            block_starts,
            constraints,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::ops;

    /// The loop-space enumeration mapped through the access functions must
    /// coincide with `Q ∩ H` — the paper's two formulations agree.
    fn check_equivalence(kernel: &Kernel) {
        let jd = JointDomain::of_kernel(kernel);
        let mut from_loops: Vec<Vec<i64>> = Vec::new();
        IterOrder::lex(kernel.n_free()).scan(kernel.extents(), |f| {
            let mut joint = Vec::new();
            for op in kernel.operands() {
                joint.extend(op.access.apply(f));
            }
            from_loops.push(joint);
        });
        let mut from_joint = jd.enumerate();
        from_loops.sort();
        from_loops.dedup();
        from_joint.sort();
        assert_eq!(from_loops, from_joint, "kernel {}", kernel.name());
    }

    #[test]
    fn scalar_product_h_matches() {
        // H = {i_1 = 0, i_2 = i_3} — Table 1 row 1
        check_equivalence(&ops::scalar_product(6, 8, 0));
    }

    #[test]
    fn convolution_h_matches() {
        // H = {i_1 = 0, i_2 = m−1−i_3} — Table 1 row 2
        check_equivalence(&ops::convolution(7, 8, 0));
    }

    #[test]
    fn matmul_h_matches() {
        // H = {a_row = b_row, a_col = c_col, b_col = c_row} — Table 1 row 3
        check_equivalence(&ops::matmul(3, 4, 2, 8, 0));
    }

    #[test]
    fn kronecker_h_matches() {
        // H = {a_1 = m1C·b_1 + c_1, a_2 = m2C·b_2 + c_2} — Table 1 row 4
        check_equivalence(&ops::kronecker(2, 2, 3, 2, 8, 0));
    }

    #[test]
    fn matmul_constraint_count() {
        let jd = JointDomain::of_kernel(&ops::matmul(3, 4, 2, 8, 0));
        // joint space: A(2) + B(2) + C(2) = 6 coords; H has rank 3
        // (i, j, k each linking two coordinate blocks)
        assert_eq!(jd.extents.len(), 6);
        assert!(jd.constraints.len() >= 3);
        // the point (A=(1,0), B=(1,1), C=(1,0)) satisfies H
        assert!(jd.contains(&[1, 0, 1, 1, 1, 0]));
        // A row ≠ B row violates H
        assert!(!jd.contains(&[0, 0, 1, 1, 1, 0]));
    }

    #[test]
    fn projections() {
        let jd = JointDomain::of_kernel(&ops::matmul(3, 4, 2, 8, 0));
        let x = [1i64, 0, 1, 1, 1, 0];
        assert_eq!(jd.project(0, &x), &[1, 0]);
        assert_eq!(jd.project(1, &x), &[1, 1]);
        assert_eq!(jd.project(2, &x), &[1, 0]);
    }
}
