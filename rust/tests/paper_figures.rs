//! Integration tests pinning the paper's figures and examples
//! (DESIGN.md E1–E4, E10) — each test re-derives a concrete claim from
//! the paper text and asserts our implementation reproduces it.

use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::conflict::ConflictAnalysis;
use latticetile::domain::{ops, Constraint, JointDomain};
use latticetile::experiments::fig3;
use latticetile::index::{Layout, Table};
use latticetile::lattice::Lattice;

/// E2 / Figure 1: the 8×5 column-major array in a 2-way, 4-set cache with
/// 2-element lines: the upper 2×5 sub-array cannot reside without
/// conflict misses although it is far below capacity.
#[test]
fn fig1_subarray_thrashes_despite_fitting_capacity() {
    let spec = CacheSpec::FIG1_TOY;
    let table = Table::new("A", &[8, 5], Layout::ColumnMajor, 8, 0);
    // sub-array working set: 5 lines out of 8-line capacity
    let mut lines = std::collections::HashSet::new();
    for j in 0..5 {
        for i in 0..2 {
            lines.insert(spec.line_of_addr(table.addr(&[i, j])));
        }
    }
    assert_eq!(lines.len(), 5);
    assert!(lines.len() <= spec.n_lines());
    // but they all collide in one set → steady-state misses
    let mut c = CacheSim::new(spec, Policy::Lru);
    for _ in 0..6 {
        for j in 0..5 {
            for i in 0..2 {
                c.access(table.addr(&[i, j]));
            }
        }
    }
    assert!(c.stats().conflict > 0, "no steady-state conflict misses");
    assert_eq!(c.stats().capacity, 0, "all misses must be conflicts");
}

/// E3 / Figure 2: joint iteration domain of two vectors A and B with
/// φ_A(0) = 0, φ_B(0) = 3 (mod N), N = 4: self-conflict stripes every 4
/// in each coordinate, cross-conflicts where the translated classes meet.
#[test]
fn fig2_joint_conflicts_of_two_vectors() {
    // model as scalar-product-like kernel: loop (a, b) over A[a], B[b];
    // bases offset so φ_B(0) ≡ 3 (mod 4) with elem-granular lines.
    let n_sets = 4i64;
    let elem = 8usize;
    // hand-build: A at element 0, B at element 3 — iterate the joint grid
    let a = Table::new("A", &[12], Layout::ColumnMajor, elem, 0);
    let b = Table::new("B", &[12], Layout::ColumnMajor, elem, 3 * elem);
    // self-conflict lattice of each operand (1-D): stride-4
    let la = Lattice::from_congruence(&[1], n_sets as i128);
    assert_eq!(la.det_abs(), 4);
    // G_A = {(x, ·) : x ≡ 0 mod 4}; G_B = {(·, y) : y + 3 ≡ 3 mod 4} = y ≡ 0;
    // cross-conflicts: φ_A(x) ≡ φ_B(y) (mod 4) ⇔ x ≡ y + 3 (mod 4).
    let mut cross = 0usize;
    for x in 0..12i64 {
        for y in 0..12i64 {
            let ca = (a.base() as i64 / elem as i64 + x).rem_euclid(n_sets);
            let cb = (b.base() as i64 / elem as i64 + y).rem_euclid(n_sets);
            let expect = (x - y - 3).rem_euclid(n_sets) == 0;
            assert_eq!(ca == cb, expect, "({x},{y})");
            if ca == cb {
                cross += 1;
            }
        }
    }
    // every x matches exactly 3 of the 12 y values
    assert_eq!(cross, 12 * 3);
}

/// E1 / Table 1: the four operations' constraint sets, as stated in the
/// paper, hold on the constructed joint domains.
#[test]
fn table1_constraint_sets() {
    // scalar product: {i_1 = 0, i_2 = i_3}
    let jd = JointDomain::of_kernel(&ops::scalar_product(5, 8, 0));
    assert!(jd.contains(&[0, 2, 2]));
    assert!(!jd.contains(&[0, 2, 3]));

    // convolution: {i_1 = 0, i_2 = m^C − 1 − i_3}
    let jd = JointDomain::of_kernel(&ops::convolution(5, 8, 0));
    assert!(jd.contains(&[0, 1, 3])); // 1 = 5-1-3
    assert!(!jd.contains(&[0, 1, 2]));

    // matmul: {a_r = b_r, a_c = c_c, b_c = c_r}
    let jd = JointDomain::of_kernel(&ops::matmul(3, 3, 3, 8, 0));
    assert!(jd.contains(&[2, 1, 2, 0, 0, 1]));
    assert!(!jd.contains(&[2, 1, 1, 0, 0, 1]));

    // kronecker: {a_1 = m1C·b_1 + c_1, a_2 = m2C·b_2 + c_2}
    let jd = JointDomain::of_kernel(&ops::kronecker(2, 2, 3, 3, 8, 0));
    assert!(jd.contains(&[3 + 2, 3 + 1, 1, 1, 2, 1])); // a=(5,4), b=(1,1), c=(2,1)
    assert!(!jd.contains(&[3 + 2, 3 + 1, 1, 1, 2, 2]));

    // Constraint helpers behave
    let c = Constraint::equal(4, 1, 3);
    assert!(c.satisfied(&[9, 5, 0, 5]));
    assert!(!c.satisfied(&[9, 5, 0, 6]));
}

/// E4 / Figure 3: exact volume numbers.
#[test]
fn fig3_exact_volumes() {
    let r = fig3::run();
    assert_eq!(r.lattice_volume, 512);
    // our exhaustive practical optimum is consistent with the paper's
    // cited band (between the chosen 416 and the theoretical best 453,
    // or above — criteria differ slightly)
    assert!(
        r.best_practical_rect_volume >= 400 && r.best_practical_rect_volume <= 512,
        "practical rect volume {} out of plausible band",
        r.best_practical_rect_volume
    );
    // the lattice tile dominates every safe rectangle
    assert!(r.lattice_volume >= r.best_rect_volume);
}

/// §1.1.3: per-set usage is non-uniform for strided access — the paper's
/// argument that aggregate capacity is a misleading metric.
#[test]
fn per_set_imbalance_under_strided_access() {
    let spec = CacheSpec::HASWELL_L1D;
    let mut sim = CacheSim::new(spec, Policy::Lru);
    // stride of 4096 bytes = same set every time
    for i in 0..1000usize {
        sim.access(i * 4096);
    }
    assert!(sim.stats().set_imbalance() > 1.0, "expected extreme imbalance");
    // uniform streaming: near-zero imbalance
    let mut sim = CacheSim::new(spec, Policy::Lru);
    for i in 0..64 * 1024usize {
        sim.access(i * 64);
    }
    assert!(sim.stats().set_imbalance() < 0.05);
}

/// §2.3 Observation 1: potential conflict ⇔ difference in L(C, φ),
/// verified through the ConflictAnalysis API on a padded matmul.
#[test]
fn observation1_conflict_iff_lattice_difference() {
    let kernel = ops::matmul_padded(8, 8, 8, 12, 10, 9, 8, 0);
    let spec = CacheSpec::new(4 * 2 * 8, 8, 2, 1); // P = 4 elements
    let ca = ConflictAnalysis::new(&kernel, &spec);
    let b_op = &ca.operands[1];
    let phi = kernel.operand(1).table.map();
    for x1 in 0..8i64 {
        for x2 in 0..8i64 {
            for y1 in 0..8i64 {
                for y2 in 0..8i64 {
                    let conflict =
                        (phi.apply(&[x1, x2]) - phi.apply(&[y1, y2])).rem_euclid(ca.period) == 0;
                    let diff = [(x1 - y1) as i128, (x2 - y2) as i128];
                    assert_eq!(
                        b_op.operand_lattice.contains(&diff),
                        conflict,
                        "x=({x1},{x2}) y=({y1},{y2})"
                    );
                }
            }
        }
    }
}
