//! Differential tests for the *generalized* packed engine: all four
//! Table-1 kernels (scalar product, convolution, matmul, Kronecker)
//! executed through the packed micro/macro pipeline — at **both element
//! types** (f32 and f64) and **every register-tile geometry** of the
//! 2-D (MR, NR) candidate grid — and compared against the
//! kernel-semantic scalar oracle ([`KernelBuffers::reference`]).
//!
//! Two comparison regimes:
//!
//! * **bit-for-bit**: the buffers are refilled with small integer-valued
//!   scalars ([`KernelBuffers::fill_ints`]), so every product and partial
//!   sum is exactly representable *at either precision* and any correct
//!   summation order produces identical bits — a mismatch of even one
//!   ULP means the engine touched the wrong element, not "rounding".
//! * **ULP-scaled**: random real fills with the [`Scalar::ulp_tol`]
//!   tolerance (per reduction depth, scaled by the result magnitude) —
//!   this is what catches a sign/offset bug that integer symmetry could
//!   mask, and it exercises the f32 rounding behaviour the bitwise runs
//!   cannot.

use latticetile::codegen::executor::{max_abs_diff, KernelBuffers, TiledExecutor};
use latticetile::codegen::{
    calibrate_dtype, kernel_views, pick_winner, run_macro, run_macro_acc, run_parallel,
    run_parallel_macro, run_parallel_macro_tuned, DType, GemmForm, MicroShape, PackedCols,
    PackedRows, ParallelTuning, Scalar,
};
use latticetile::domain::ops;
use latticetile::domain::Kernel;
use latticetile::lattice::IMat;
use latticetile::testutil::prop_check;
use latticetile::tiling::{LevelPlan, TileBasis, TiledSchedule};

/// Integer-filled scalar oracle for `kernel` (exact, order-independent).
fn int_oracle<T: Scalar>(bufs: &mut KernelBuffers<T>, range: u64, seed: u64) -> Vec<T> {
    bufs.fill_ints(range, seed);
    bufs.reference()
}

/// Run `make(T::ELEM)` under `basis` through the packed engine at one
/// dtype (both macro and per-tile L1 paths, every (MR, NR) candidate
/// geometry) and require bitwise equality with the scalar oracle — a
/// wrong const-generic arm would misread the geometry-specific panel
/// layout, so this pins the dispatch itself, not just the arithmetic.
fn check_bitwise_t<T: Scalar>(make: &dyn Fn(usize) -> Kernel, basis: &TileBasis, label: &str) {
    let kernel = make(T::ELEM);
    let sched = TiledSchedule::new(basis.clone());
    for micro in MicroShape::CANDIDATES {
        let exec = TiledExecutor::new(sched.clone()).with_micro_shape(micro);
        let mut bufs = KernelBuffers::<T>::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, 0xD1FF ^ label.len() as u64);
        exec.run(&mut bufs, &kernel);
        assert_eq!(
            bufs.output(),
            want,
            "{label} ({micro:?}, {}B elem): macro path differs from the oracle bitwise",
            T::ELEM
        );
        bufs.reset_output();
        exec.run_l1_only(&mut bufs, &kernel);
        assert_eq!(
            bufs.output(),
            want,
            "{label} ({micro:?}, {}B elem): per-tile path differs from the oracle bitwise",
            T::ELEM
        );
    }
}

/// [`check_bitwise_t`] at f64 *and* f32 — the kernel constructor takes
/// the element size so each dtype gets its own (lattice-correct) kernel.
fn check_bitwise(make: impl Fn(usize) -> Kernel, basis: TileBasis, label: &str) {
    check_bitwise_t::<f64>(&make, &basis, label);
    check_bitwise_t::<f32>(&make, &basis, label);
}

/// Random-real differential run at one dtype: engine vs oracle within
/// the ULP-scaled tolerance for the kernel's reduction depth.
fn check_real_t<T: Scalar>(make: &dyn Fn(usize) -> Kernel, basis: &TileBasis, label: &str) {
    let kernel = make(T::ELEM);
    let depth = GemmForm::of(&kernel).map(|gf| gf.k).unwrap_or(1);
    let sched = TiledSchedule::new(basis.clone());
    for micro in MicroShape::CANDIDATES {
        let exec = TiledExecutor::new(sched.clone()).with_micro_shape(micro);
        let mut bufs = KernelBuffers::<T>::from_kernel(&kernel); // random fill
        let want = bufs.reference();
        exec.run(&mut bufs, &kernel);
        // the random fill is in [-0.5, 0.5], so every partial sum is
        // bounded by depth·0.25 — scale the per-unit ULP tolerance by the
        // worst-case partial-sum magnitude
        let tol = T::ulp_tol(depth) * (1.0 + 0.25 * depth as f64);
        let got = bufs.output();
        let diff = max_abs_diff(&got, &want);
        assert!(
            diff < tol,
            "{label} ({micro:?}, {}B elem): |Δ| = {diff} ≥ ulp tol {tol}",
            T::ELEM
        );
    }
}

#[test]
fn convolution_executes_through_the_packed_engine() {
    // the engine must classify convolution as GEMM-form (degenerate
    // 1×1×n dot with a reversed column operand), not fall back
    assert!(GemmForm::of(&ops::convolution(100, 8, 0)).is_some());
    check_bitwise(
        |elem| ops::convolution(100, elem, 0),
        TileBasis::rect(&[16]),
        "conv n=100 tile=16",
    );
}

#[test]
fn kronecker_executes_through_the_packed_engine() {
    assert!(GemmForm::of(&ops::kronecker(5, 3, 7, 4, 8, 0)).is_some());
    check_bitwise(
        |elem| ops::kronecker(5, 3, 7, 4, elem, 0),
        TileBasis::rect(&[2, 2, 4, 3]),
        "kron 5x3x7x4",
    );
}

/// Convolution across random sizes, bases, and tile widths — including
/// tiles larger than the domain and size-1 domains — at both dtypes.
#[test]
fn prop_convolution_bitwise() {
    prop_check(20, 0xC04, |case, rng| {
        let n = rng.range_i64(1, 300);
        let base16 = rng.range_i64(0, 16) as usize;
        let tile = rng.range_i64(1, 48);
        check_bitwise(
            move |elem| ops::convolution(n, elem, base16 * elem),
            TileBasis::rect(&[tile]),
            &format!("case {case}: conv n={n} tile={tile}"),
        );
    });
}

/// Scalar product (Table 1 row 1) rides the same degenerate-dot path.
#[test]
fn prop_scalar_product_bitwise() {
    prop_check(10, 0x5CA, |case, rng| {
        let n = rng.range_i64(1, 200);
        let base8 = rng.range_i64(0, 8) as usize;
        let tile = rng.range_i64(1, 32);
        check_bitwise(
            move |elem| ops::scalar_product(n, elem, base8 * elem),
            TileBasis::rect(&[tile]),
            &format!("case {case}: scalar n={n} tile={tile}"),
        );
    });
}

/// Kronecker across random factor shapes and non-multiple rect tiles:
/// segmented runs (the output jumps every m1c rows), swapped operand
/// roles, per-column output bases — at both dtypes.
#[test]
fn prop_kronecker_bitwise() {
    prop_check(15, 0x12C4, |case, rng| {
        let m1b = rng.range_i64(1, 7);
        let m2b = rng.range_i64(1, 6);
        let m1c = rng.range_i64(1, 9);
        let m2c = rng.range_i64(1, 6);
        let tile = [
            rng.range_i64(1, 4).min(m1b),
            rng.range_i64(1, 4).min(m2b),
            rng.range_i64(1, 6).min(m1c),
            rng.range_i64(1, 4).min(m2c),
        ];
        check_bitwise(
            move |elem| ops::kronecker(m1b, m2b, m1c, m2c, elem, 0),
            TileBasis::rect(&tile),
            &format!("case {case}: kron {m1b}x{m2b}x{m1c}x{m2c} tile={tile:?}"),
        );
    });
}

/// Kronecker under a *skewed* 4-D basis: outside the 3-D replay class,
/// must take the exact per-point fallback and stay correct — both dtypes.
#[test]
fn prop_kronecker_skewed_fallback() {
    fn run_case<T: Scalar>(kernel: &Kernel, sched: &TiledSchedule, case: usize, seed: u64) {
        let exec = TiledExecutor::new(sched.clone());
        let mut bufs = KernelBuffers::<T>::from_kernel(kernel);
        let want = int_oracle(&mut bufs, 3, seed);
        exec.run(&mut bufs, kernel);
        assert_eq!(
            bufs.output(),
            want,
            "case {case}: skewed kronecker fallback differs ({}B elem)",
            T::ELEM
        );
    }
    prop_check(8, 0x5E4D, |case, rng| {
        let m1b = rng.range_i64(2, 6);
        let m2b = rng.range_i64(2, 5);
        let m1c = rng.range_i64(2, 7);
        let m2c = rng.range_i64(2, 5);
        let basis = loop {
            let b = IMat::from_rows(&[
                &[rng.range_i64(2, 4) as i128, rng.range_i64(0, 2) as i128, 0, 0],
                &[rng.range_i64(0, 2) as i128, rng.range_i64(2, 4) as i128, 0, 0],
                &[0, 0, rng.range_i64(2, 4) as i128, 0],
                &[0, 0, 0, rng.range_i64(2, 4) as i128],
            ]);
            if b.det() != 0 && (b[(0, 1)] != 0 || b[(1, 0)] != 0) {
                break b;
            }
        };
        let sched = TiledSchedule::new(TileBasis::from_cols(basis));
        let seed = 0xAB ^ case as u64;
        run_case::<f64>(&ops::kronecker(m1b, m2b, m1c, m2c, 8, 0), &sched, case, seed);
        run_case::<f32>(&ops::kronecker(m1b, m2b, m1c, m2c, 4, 0), &sched, case, seed);
    });
}

/// Convolution's reversed operand is where an offset bug hides behind
/// symmetric data: check with asymmetric *real* data too (ULP tolerance,
/// not bitwise — summation order differs between oracle and engine).
#[test]
fn convolution_reversal_with_real_data() {
    for tile in [10i64, 129] {
        check_real_t::<f64>(
            &|elem| ops::convolution(129, elem, 8 * elem),
            &TileBasis::rect(&[tile]),
            "conv reversal",
        );
        check_real_t::<f32>(
            &|elem| ops::convolution(129, elem, 8 * elem),
            &TileBasis::rect(&[tile]),
            "conv reversal",
        );
    }
}

/// Random real fills for every Table-1 kernel at both dtypes: the
/// engine's reassociated summation must stay within the ULP-scaled
/// tolerance of the sequential oracle.
#[test]
fn real_fills_within_ulp_tolerance_all_kernels() {
    let cases: Vec<(Box<dyn Fn(usize) -> Kernel>, TileBasis, &str)> = vec![
        (
            Box::new(|elem| ops::matmul_padded(23, 17, 19, 26, 24, 20, elem, 0)),
            TileBasis::rect(&[10, 6, 5]),
            "matmul 23x17x19 padded",
        ),
        (
            Box::new(|elem| ops::convolution(257, elem, 0)),
            TileBasis::rect(&[32]),
            "conv n=257",
        ),
        (
            Box::new(|elem| ops::scalar_product(123, elem, 0)),
            TileBasis::rect(&[16]),
            "scalar n=123",
        ),
        (
            Box::new(|elem| ops::kronecker(4, 3, 6, 5, elem, 0)),
            TileBasis::rect(&[2, 2, 4, 3]),
            "kron 4x3x6x5",
        ),
    ];
    for (make, basis, label) in &cases {
        check_real_t::<f64>(make.as_ref(), basis, label);
        check_real_t::<f32>(make.as_ref(), basis, label);
    }
}

/// The parallel paths for the generalized kernels at both dtypes:
/// Kronecker through the band macro path and the per-tile group path,
/// convolution degrading to a single worker — all bitwise against the
/// oracle.
#[test]
fn prop_parallel_generalized_kernels() {
    fn kron_case<T: Scalar>(
        dims: (i64, i64, i64, i64),
        threads: usize,
        case: usize,
        seed: u64,
    ) {
        let kernel = ops::kronecker(dims.0, dims.1, dims.2, dims.3, T::ELEM, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[2, 2, 3, 2]));
        for pv in [0usize, 2] {
            let mut bufs = KernelBuffers::<T>::from_kernel(&kernel);
            let want = int_oracle(&mut bufs, 3, seed);
            run_parallel(&mut bufs, &kernel, &sched, threads, pv);
            assert_eq!(
                bufs.output(),
                want,
                "case {case}: parallel kronecker pv={pv} threads={threads} ({}B elem)",
                T::ELEM
            );
        }
    }
    fn conv_case<T: Scalar>(n: i64, threads: usize, case: usize, seed: u64) {
        let kernel = ops::convolution(n, T::ELEM, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[7]));
        let mut bufs = KernelBuffers::<T>::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, seed);
        run_parallel(&mut bufs, &kernel, &sched, threads, 0);
        assert_eq!(
            bufs.output(),
            want,
            "case {case}: parallel convolution ({}B elem)",
            T::ELEM
        );
    }
    prop_check(8, 0x9A81, |case, rng| {
        let threads = rng.range_usize(1, 4);
        // kronecker: partition over a column axis (i → band macro path)
        // and over a row axis (k → per-tile group path)
        let dims = (
            rng.range_i64(2, 6),
            rng.range_i64(2, 5),
            rng.range_i64(2, 7),
            rng.range_i64(2, 5),
        );
        kron_case::<f64>(dims, threads, case, 0x77 ^ case as u64);
        kron_case::<f32>(dims, threads, case, 0x77 ^ case as u64);
        // convolution: scalar output → must degrade serially, stay exact
        let n = rng.range_i64(1, 120);
        conv_case::<f64>(n, threads, case, 0x99 ^ case as u64);
        conv_case::<f32>(n, threads, case, 0x99 ^ case as u64);
    });
}

/// Explicit macro shapes for Kronecker through `run_parallel_macro`,
/// both register-tile width classes, both dtypes.
#[test]
fn prop_parallel_macro_kronecker() {
    fn run_case<T: Scalar>(
        dims: (i64, i64, i64, i64),
        lp: LevelPlan,
        micro: MicroShape,
        threads: usize,
        case: usize,
        seed: u64,
    ) {
        let kernel = ops::kronecker(dims.0, dims.1, dims.2, dims.3, T::ELEM, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[2, 2, 3, 2]));
        let mut bufs = KernelBuffers::<T>::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, seed);
        run_parallel_macro(&mut bufs, &kernel, &sched, threads, Some(lp), micro);
        assert_eq!(
            bufs.output(),
            want,
            "case {case}: parallel macro kronecker lp={lp:?} micro={micro:?} ({}B elem)",
            T::ELEM
        );
    }
    prop_check(6, 0xFACE, |case, rng| {
        let dims = (
            rng.range_i64(2, 6),
            rng.range_i64(2, 6),
            rng.range_i64(2, 8),
            rng.range_i64(2, 6),
        );
        let gf = GemmForm::of(&ops::kronecker(dims.0, dims.1, dims.2, dims.3, 8, 0)).unwrap();
        let mc = rng.range_usize(2, 16).min(gf.m.max(2));
        let nc = rng.range_usize(2, 14).min(gf.n.max(2));
        let lp = LevelPlan {
            l1_tile: (
                rng.range_usize(2, 12),
                rng.range_usize(2, 12),
                1,
            ),
            mc,
            kc: 1,
            nc,
            // super-bands of 1–3 macro blocks, frequently not dividing
            // the GEMM extents
            m3: mc * rng.range_usize(1, 3),
            n3: nc * rng.range_usize(1, 3),
        };
        let micro = *rng.pick(&MicroShape::CANDIDATES);
        let threads = rng.range_usize(1, 4);
        let seed = 0x31 ^ case as u64;
        run_case::<f64>(dims, lp, micro, threads, case, seed);
        run_case::<f32>(dims, lp, micro, threads, case, seed);
    });
}

/// The L3 super-band parallel scheduler at both dtypes and both
/// register-tile width classes: workers claim `m3×n3` super-bands and
/// pack their own row slices — bitwise against the oracle (integer
/// fills), across thread counts including oversubscription, with grid
/// and pack-count invariants pinned.
#[test]
fn prop_parallel_super_band_matmul_bitwise() {
    fn run_case<T: Scalar>(
        (m, k, n): (i64, i64, i64),
        lp: LevelPlan,
        micro: MicroShape,
        threads: usize,
        case: usize,
        seed: u64,
    ) {
        let kernel = ops::matmul(m, k, n, T::ELEM, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = KernelBuffers::<T>::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, seed);
        // deterministic tuning: the pack-ahead pipeline ON, stealing off
        // — the mode whose pack totals are exact schedule invariants
        let stats = run_parallel_macro_tuned(
            &mut bufs,
            &kernel,
            &sched,
            threads,
            Some(lp),
            micro,
            ParallelTuning::deterministic(),
        );
        assert_eq!(stats.steals, 0, "case {case}: stealing disabled");
        // m3/n3 are constructed as mc/nc multiples, so the claimed grid
        // is exactly the ceil-division cover of the GEMM extents
        let bands = (m as usize).div_ceil(lp.m3) * (n as usize).div_ceil(lp.n3);
        assert_eq!(stats.super_bands, bands, "case {case} ({}B elem)", T::ELEM);
        assert_eq!(stats.workers, threads.min(bands));
        assert_eq!(
            stats.row_slice_packs,
            bands as u64 * (k as u64).div_ceil(lp.kc as u64),
            "case {case}: each band's row slice packed once per kc step ({}B elem)",
            T::ELEM
        );
        assert_eq!(
            bufs.output(),
            want,
            "case {case}: super-band matmul {m}x{k}x{n} t={threads} {micro:?} ({}B elem)",
            T::ELEM
        );
    }
    prop_check(8, 0x5BA2, |case, rng| {
        let m = rng.range_i64(17, 48);
        let k = rng.range_i64(3, 24);
        let n = rng.range_i64(9, 40);
        let mc = rng.range_usize(4, 12);
        let nc = rng.range_usize(3, 10);
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc,
            kc: rng.range_usize(2, 9),
            nc,
            // super-bands of 1–2 macro blocks: frequently several bands
            // per axis, frequently not dividing the extents
            m3: mc * rng.range_usize(1, 2),
            n3: nc * rng.range_usize(1, 2),
        };
        let micro = *rng.pick(&MicroShape::CANDIDATES);
        let threads = rng.range_usize(1, 6);
        let seed = 0xB17 ^ case as u64;
        run_case::<f64>((m, k, n), lp, micro, threads, case, seed);
        run_case::<f32>((m, k, n), lp, micro, threads, case, seed);
    });
}

/// The pipelined scheduler's numerics contract, property-tested over
/// random super-band grids at both dtypes: the serial macro nest, the
/// synchronous parallel loop, the deterministic pipeline, and the full
/// pipeline with sub-band stealing all produce **bitwise identical**
/// outputs — pipelining and stealing reorder packing and split row
/// ranges, but every output element's ascending-`k0` accumulation order
/// is untouched.
#[test]
fn prop_pipelined_schedule_bitwise_matches_serial_nest() {
    fn run_case<T: Scalar>(
        (m, k, n): (i64, i64, i64),
        lp: LevelPlan,
        micro: MicroShape,
        threads: usize,
        case: usize,
        seed: u64,
    ) {
        let kernel = ops::matmul(m, k, n, T::ELEM, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        // the serial three-level nest is the bitwise oracle schedule
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
        let mut ser = KernelBuffers::<T>::from_kernel(&kernel);
        let exact = int_oracle(&mut ser, 3, seed);
        run_macro(
            &mut ser.arena,
            &plan,
            &lp,
            micro,
            &mut PackedRows::new(),
            &mut PackedCols::new(),
        );
        let want = ser.output();
        assert_eq!(want, exact, "case {case}: serial nest vs scalar oracle");
        for tuning in [
            ParallelTuning::synchronous(),
            ParallelTuning::deterministic(),
            ParallelTuning::default(),
        ] {
            let mut bufs = KernelBuffers::<T>::from_kernel(&kernel);
            bufs.fill_ints(3, seed);
            run_parallel_macro_tuned(&mut bufs, &kernel, &sched, threads, Some(lp), micro, tuning);
            assert_eq!(
                bufs.output(),
                want,
                "case {case}: {tuning:?} t={threads} must be bitwise the serial nest \
                 ({m}x{k}x{n}, {micro:?}, {}B elem)",
                T::ELEM
            );
        }
    }
    prop_check(6, 0x717E, |case, rng| {
        let m = rng.range_i64(17, 56);
        let k = rng.range_i64(3, 26);
        let n = rng.range_i64(9, 44);
        let mc = rng.range_usize(4, 12);
        let nc = rng.range_usize(3, 10);
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc,
            kc: rng.range_usize(2, 9),
            nc,
            m3: mc * rng.range_usize(1, 3),
            n3: nc * rng.range_usize(1, 2),
        };
        let micro = *rng.pick(&MicroShape::CANDIDATES);
        let threads = rng.range_usize(1, 6);
        let seed = 0x5E1A ^ case as u64;
        run_case::<f64>((m, k, n), lp, micro, threads, case, seed);
        run_case::<f32>((m, k, n), lp, micro, threads, case, seed);
    });
}

/// Matmul itself is just one instantiation now: bitwise through the same
/// generalized engine at both dtypes (integer fill makes the
/// slice/register summation reassociation exact at either precision).
#[test]
fn prop_matmul_bitwise_through_generalized_engine() {
    prop_check(10, 0x3A7, |case, rng| {
        let m = rng.range_i64(1, 40);
        let k = rng.range_i64(1, 30);
        let n = rng.range_i64(1, 36);
        let lda = m + rng.range_i64(0, 4);
        let ldb = m + rng.range_i64(0, 4);
        let ldc = k + rng.range_i64(0, 4);
        let tile = [
            rng.range_i64(1, 14).min(m),
            rng.range_i64(1, 10).min(n),
            rng.range_i64(1, 9).min(k),
        ];
        check_bitwise(
            move |elem| ops::matmul_padded(m, k, n, lda, ldb, ldc, elem, 0),
            TileBasis::rect(&tile),
            &format!("case {case}: matmul {m}x{k}x{n}"),
        );
    });
}

/// The `f32acc64` mixed mode on an ill-conditioned fill: f32 storage,
/// f64 register accumulation, one rounding per `kc` slice. With
/// `kc ≥ k` the whole reduction is a single slice, so the wide result
/// is the correctly rounded f32 of an exact-product f64 sum — its error
/// against an f64 oracle (computed from the *same* f32 operand values)
/// must be at most 1 ulp of the result, and never worse than the pure
/// f32 run's error, at every (MR, NR) candidate geometry.
#[test]
fn wide_accumulation_is_at_least_as_accurate_as_pure_f32() {
    let (m, k, n) = (24i64, 48i64, 20i64);
    let kernel = ops::matmul(m, k, n, 4, 0);
    let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
    // Ill-conditioned mixed-sign fill: magnitudes spread over 1e-2..1e2,
    // so pure-f32 partial sums lose the small addends' low bits and the
    // accumulation-order rounding error is actually visible.
    let mut state = 0xACCu64;
    let mut rnd = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        let mag = 10f64.powi(((state >> 8) % 5) as i32 - 2);
        let sign = if state & 1 == 0 { 1.0 } else { -1.0 };
        sign * mag * (1.0 + ((state >> 16) & 0xFFFF) as f64 / 65536.0)
    };
    for i in 1..=2 {
        for v in bufs.operand_mut(i) {
            *v = rnd() as f32;
        }
    }
    bufs.reset_output();

    // f64 oracle over the *rounded f32* operand values — this isolates
    // accumulation error from input-quantization error.
    let kernel64 = ops::matmul(m, k, n, 8, 0);
    let mut oracle = KernelBuffers::<f64>::from_kernel(&kernel64);
    for i in 1..=2 {
        let src: Vec<f32> = bufs.operand_mut(i).to_vec();
        let dst = oracle.operand_mut(i);
        assert_eq!(src.len(), dst.len(), "operand {i} spans must mirror");
        for (d, s) in dst.iter_mut().zip(&src) {
            *d = *s as f64;
        }
    }
    oracle.reset_output();
    let want = oracle.reference();

    let gf = GemmForm::of(&kernel).unwrap();
    let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
    // kc = k: the single-slice regime where the one-rounding-per-slice
    // contract makes the wide result correctly rounded end to end
    let lp = LevelPlan {
        l1_tile: (8, 8, 8),
        mc: 16,
        kc: k as usize,
        nc: 8,
        m3: 16,
        n3: 8,
    };
    let max_err = |out: &[f32]| -> f64 {
        out.iter()
            .zip(&want)
            .map(|(&g, &w)| (g as f64 - w).abs())
            .fold(0.0, f64::max)
    };
    for micro in MicroShape::CANDIDATES {
        let mut pure = bufs.clone();
        run_macro_acc(
            &mut pure.arena,
            &plan,
            &lp,
            micro,
            &mut PackedRows::new(),
            &mut PackedCols::new(),
            false,
        );
        let mut wide = bufs.clone();
        run_macro_acc(
            &mut wide.arena,
            &plan,
            &lp,
            micro,
            &mut PackedRows::new(),
            &mut PackedCols::new(),
            true,
        );
        let (perr, werr) = (max_err(&pure.output()), max_err(&wide.output()));
        assert!(
            werr <= perr,
            "{micro:?}: wide accumulation worse than pure f32 ({werr:e} > {perr:e})"
        );
        // per-element: correctly rounded ⇒ within 1 ulp of the oracle
        // (ε-relative, plus absolute slack for near-cancelled results)
        for (&g, &w) in wide.output().iter().zip(&want) {
            let tol = f32::EPSILON as f64 * w.abs() + 1e-4;
            assert!(
                (g as f64 - w).abs() <= tol,
                "{micro:?}: wide result {g} vs oracle {w} off by more than 1 ulp"
            );
        }
    }
}

/// The autotune grid race is deterministic and its recorded winner is
/// what the planner actually dispatches per dtype: `pick_winner` obeys
/// the tie-keeps-default / >5%-challenger rule on fixed rate tables, a
/// registry override surfaces in both `Plan.micro` and `describe()`,
/// and a live `calibrate_dtype` race lands inside the candidate grid.
#[test]
fn autotuned_winner_is_dispatched_per_dtype() {
    use latticetile::cache::CacheSpec;
    use latticetile::coordinator::Planner;
    use latticetile::runtime::Registry;

    // ties keep the incumbent default — repeatedly, same input same winner
    let flat: Vec<(MicroShape, f64)> =
        MicroShape::CANDIDATES.iter().map(|&s| (s, 1.0)).collect();
    for _ in 0..5 {
        assert_eq!(pick_winner(&flat), MicroShape::Mr8Nr4, "tie must keep the default");
    }
    // a challenger inside the 5% margin is noise, not a winner
    let mut close = flat.clone();
    close[3].1 = 1.04;
    assert_eq!(pick_winner(&close), MicroShape::Mr8Nr4);
    // a >5% challenger wins; the best of several challengers wins
    let mut tall = flat.clone();
    tall[2].1 = 1.08;
    tall[3].1 = 1.21;
    assert_eq!(pick_winner(&tall), MicroShape::Mr16Nr6);

    // recorded winners dispatch per dtype through the planner
    let reg = Registry::default();
    reg.set_micro_shape_for(DType::F32, MicroShape::Mr16Nr6);
    reg.set_micro_shape_for(DType::F64, MicroShape::Mr8Nr6);
    let planner = Planner::new(CacheSpec::HASWELL_L1D);
    let p32 = planner.plan(&reg, 64, 64, 64, DType::F32);
    assert_eq!(p32.micro, MicroShape::Mr16Nr6);
    assert!(
        p32.describe().contains("16x6"),
        "f32 plan must report its tall winner: {}",
        p32.describe()
    );
    let p64 = planner.plan(&reg, 64, 64, 64, DType::F64);
    assert_eq!(p64.micro, MicroShape::Mr8Nr6);
    assert!(
        p64.describe().contains("8x6"),
        "f64 plan must report its winner: {}",
        p64.describe()
    );

    // a live race always lands inside the grid, at either dtype
    assert!(MicroShape::CANDIDATES.contains(&calibrate_dtype::<f32>(30)));
    assert!(MicroShape::CANDIDATES.contains(&calibrate_dtype::<f64>(30)));
}

/// The parallel matmul path at f32, both micro width classes, threads
/// > 1 — the serving dtype through the threaded band engine.
#[test]
fn prop_parallel_matmul_f32() {
    prop_check(6, 0xF32A, |case, rng| {
        let m = rng.range_i64(8, 36);
        let k = rng.range_i64(8, 30);
        let n = rng.range_i64(8, 33);
        let kernel = ops::matmul(m, k, n, 4, 0);
        let threads = rng.range_usize(1, 4);
        let tile = [
            rng.range_i64(2, 12).min(m),
            rng.range_i64(2, 12).min(n),
            rng.range_i64(2, 12).min(k),
        ];
        let sched = TiledSchedule::new(TileBasis::rect(&tile));
        let micro = *rng.pick(&MicroShape::CANDIDATES);
        let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, 0x55 ^ case as u64);
        latticetile::codegen::run_parallel_micro(&mut bufs, &kernel, &sched, threads, 1, micro);
        assert_eq!(
            bufs.output(),
            want,
            "case {case}: parallel f32 matmul {m}x{k}x{n} threads={threads} micro={micro:?}"
        );
    });
}
