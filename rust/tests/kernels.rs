//! Differential tests for the *generalized* packed engine: convolution
//! and Kronecker product — the non-matmul rows of the paper's Table 1 —
//! executed through the packed micro/macro pipeline and compared against
//! the kernel-semantic scalar oracle ([`KernelBuffers::reference`]).
//!
//! The engine paths are compared **bit-for-bit**: the buffers are
//! refilled with small integer-valued f64 ([`KernelBuffers::fill_ints`]),
//! so every product and partial sum is exactly representable and any
//! correct summation order produces identical bits — a mismatch of even
//! one ULP means the engine touched the wrong element, not "rounding".
//! Random-real runs with a tolerance are layered on top for the shapes
//! where integer fills could mask a sign/offset bug hidden by symmetry.

use latticetile::codegen::executor::{max_abs_diff, KernelBuffers, TiledExecutor};
use latticetile::codegen::{run_parallel, run_parallel_macro, GemmForm, MicroShape};
use latticetile::domain::ops;
use latticetile::domain::Kernel;
use latticetile::lattice::IMat;
use latticetile::testutil::prop_check;
use latticetile::tiling::{LevelPlan, TileBasis, TiledSchedule};

/// Integer-filled scalar oracle for `kernel` (exact, order-independent).
fn int_oracle(bufs: &mut KernelBuffers, range: u64, seed: u64) -> Vec<f64> {
    bufs.fill_ints(range, seed);
    bufs.reference()
}

/// Run `kernel` under `basis` through the packed engine (both macro and
/// per-tile L1 paths, both register-tile widths) and require bitwise
/// equality with the scalar oracle.
fn check_bitwise(kernel: &Kernel, basis: TileBasis, label: &str) {
    let sched = TiledSchedule::new(basis);
    for micro in [MicroShape::Mr8Nr4, MicroShape::Mr8Nr6] {
        let exec = TiledExecutor::new(sched.clone()).with_micro_shape(micro);
        let mut bufs = KernelBuffers::from_kernel(kernel);
        let want = int_oracle(&mut bufs, 3, 0xD1FF ^ label.len() as u64);
        exec.run(&mut bufs, kernel);
        assert_eq!(
            bufs.output(),
            want,
            "{label} ({micro:?}): macro path differs from the oracle bitwise"
        );
        bufs.reset_output();
        exec.run_l1_only(&mut bufs, kernel);
        assert_eq!(
            bufs.output(),
            want,
            "{label} ({micro:?}): per-tile path differs from the oracle bitwise"
        );
    }
}

#[test]
fn convolution_executes_through_the_packed_engine() {
    // the engine must classify convolution as GEMM-form (degenerate
    // 1×1×n dot with a reversed column operand), not fall back
    let k = ops::convolution(100, 8, 0);
    assert!(GemmForm::of(&k).is_some());
    check_bitwise(&k, TileBasis::rect(&[16]), "conv n=100 tile=16");
}

#[test]
fn kronecker_executes_through_the_packed_engine() {
    let k = ops::kronecker(5, 3, 7, 4, 8, 0);
    assert!(GemmForm::of(&k).is_some());
    check_bitwise(&k, TileBasis::rect(&[2, 2, 4, 3]), "kron 5x3x7x4");
}

/// Convolution across random sizes, bases, and tile widths — including
/// tiles larger than the domain and size-1 domains.
#[test]
fn prop_convolution_bitwise() {
    prop_check(20, 0xC04, |case, rng| {
        let n = rng.range_i64(1, 300);
        let base = rng.range_i64(0, 16) as usize * 8;
        let kernel = ops::convolution(n, 8, base);
        let tile = rng.range_i64(1, 48);
        check_bitwise(
            &kernel,
            TileBasis::rect(&[tile]),
            &format!("case {case}: conv n={n} tile={tile}"),
        );
    });
}

/// Scalar product (Table 1 row 1) rides the same degenerate-dot path.
#[test]
fn prop_scalar_product_bitwise() {
    prop_check(10, 0x5CA, |case, rng| {
        let n = rng.range_i64(1, 200);
        let kernel = ops::scalar_product(n, 8, rng.range_i64(0, 8) as usize * 8);
        let tile = rng.range_i64(1, 32);
        check_bitwise(
            &kernel,
            TileBasis::rect(&[tile]),
            &format!("case {case}: scalar n={n} tile={tile}"),
        );
    });
}

/// Kronecker across random factor shapes and non-multiple rect tiles:
/// segmented runs (the output jumps every m1c rows), swapped operand
/// roles, per-column output bases.
#[test]
fn prop_kronecker_bitwise() {
    prop_check(15, 0x12C4, |case, rng| {
        let m1b = rng.range_i64(1, 7);
        let m2b = rng.range_i64(1, 6);
        let m1c = rng.range_i64(1, 9);
        let m2c = rng.range_i64(1, 6);
        let kernel = ops::kronecker(m1b, m2b, m1c, m2c, 8, 0);
        let tile = [
            rng.range_i64(1, 4).min(m1b),
            rng.range_i64(1, 4).min(m2b),
            rng.range_i64(1, 6).min(m1c),
            rng.range_i64(1, 4).min(m2c),
        ];
        check_bitwise(
            &kernel,
            TileBasis::rect(&tile),
            &format!("case {case}: kron {m1b}x{m2b}x{m1c}x{m2c} tile={tile:?}"),
        );
    });
}

/// Kronecker under a *skewed* 4-D basis: outside the 3-D replay class,
/// must take the exact per-point fallback and stay correct.
#[test]
fn prop_kronecker_skewed_fallback() {
    prop_check(8, 0x5E4D, |case, rng| {
        let m1b = rng.range_i64(2, 6);
        let m2b = rng.range_i64(2, 5);
        let m1c = rng.range_i64(2, 7);
        let m2c = rng.range_i64(2, 5);
        let kernel = ops::kronecker(m1b, m2b, m1c, m2c, 8, 0);
        let basis = loop {
            let b = IMat::from_rows(&[
                &[rng.range_i64(2, 4) as i128, rng.range_i64(0, 2) as i128, 0, 0],
                &[rng.range_i64(0, 2) as i128, rng.range_i64(2, 4) as i128, 0, 0],
                &[0, 0, rng.range_i64(2, 4) as i128, 0],
                &[0, 0, 0, rng.range_i64(2, 4) as i128],
            ]);
            if b.det() != 0 && (b[(0, 1)] != 0 || b[(1, 0)] != 0) {
                break b;
            }
        };
        let sched = TiledSchedule::new(TileBasis::from_cols(basis));
        let exec = TiledExecutor::new(sched);
        let mut bufs = KernelBuffers::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, 0xAB ^ case as u64);
        exec.run(&mut bufs, &kernel);
        assert_eq!(
            bufs.output(),
            want,
            "case {case}: skewed kronecker fallback differs"
        );
    });
}

/// Convolution's reversed operand is where an offset bug hides behind
/// symmetric data: check with asymmetric *real* data too (tolerance, not
/// bitwise — summation order differs between oracle and sliced engine).
#[test]
fn convolution_reversal_with_real_data() {
    let n = 129i64;
    let kernel = ops::convolution(n, 8, 64);
    let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[10])));
    let mut bufs = KernelBuffers::from_kernel(&kernel);
    let want = bufs.reference();
    exec.run(&mut bufs, &kernel);
    assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
}

/// The parallel paths for the generalized kernels: Kronecker through the
/// band macro path and the per-tile group path, convolution degrading to
/// a single worker — all bitwise against the oracle.
#[test]
fn prop_parallel_generalized_kernels() {
    prop_check(8, 0x9A81, |case, rng| {
        let threads = rng.range_usize(1, 4);
        // kronecker: partition over a column axis (i → band macro path)
        // and over a row axis (k → per-tile group path)
        let kernel = ops::kronecker(
            rng.range_i64(2, 6),
            rng.range_i64(2, 5),
            rng.range_i64(2, 7),
            rng.range_i64(2, 5),
            8,
            0,
        );
        let sched = TiledSchedule::new(TileBasis::rect(&[2, 2, 3, 2]));
        for pv in [0usize, 2] {
            let mut bufs = KernelBuffers::from_kernel(&kernel);
            let want = int_oracle(&mut bufs, 3, 0x77 ^ case as u64);
            run_parallel(&mut bufs, &kernel, &sched, threads, pv);
            assert_eq!(
                bufs.output(),
                want,
                "case {case}: parallel kronecker pv={pv} threads={threads}"
            );
        }
        // convolution: scalar output → must degrade serially, stay exact
        let kernel = ops::convolution(rng.range_i64(1, 120), 8, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[7]));
        let mut bufs = KernelBuffers::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, 0x99 ^ case as u64);
        run_parallel(&mut bufs, &kernel, &sched, threads, 0);
        assert_eq!(bufs.output(), want, "case {case}: parallel convolution");
    });
}

/// Explicit macro shapes for Kronecker through `run_parallel_macro`, both
/// register-tile widths.
#[test]
fn prop_parallel_macro_kronecker() {
    prop_check(6, 0xFACE, |case, rng| {
        let kernel = ops::kronecker(
            rng.range_i64(2, 6),
            rng.range_i64(2, 6),
            rng.range_i64(2, 8),
            rng.range_i64(2, 6),
            8,
            0,
        );
        let gf = GemmForm::of(&kernel).unwrap();
        let lp = LevelPlan {
            l1_tile: (
                rng.range_usize(2, 12),
                rng.range_usize(2, 12),
                1,
            ),
            mc: rng.range_usize(2, 16).min(gf.m.max(2)),
            kc: 1,
            nc: rng.range_usize(2, 14).min(gf.n.max(2)),
        };
        let sched = TiledSchedule::new(TileBasis::rect(&[2, 2, 3, 2]));
        let micro = *rng.pick(&[MicroShape::Mr8Nr4, MicroShape::Mr8Nr6]);
        let threads = rng.range_usize(1, 4);
        let mut bufs = KernelBuffers::from_kernel(&kernel);
        let want = int_oracle(&mut bufs, 3, 0x31 ^ case as u64);
        run_parallel_macro(&mut bufs, &kernel, &sched, threads, Some(lp), micro);
        assert_eq!(
            bufs.output(),
            want,
            "case {case}: parallel macro kronecker lp={lp:?} micro={micro:?}"
        );
    });
}

/// Matmul itself is just one instantiation now: bitwise through the same
/// generalized engine (integer fill makes the slice/register summation
/// reassociation exact).
#[test]
fn prop_matmul_bitwise_through_generalized_engine() {
    prop_check(10, 0x3A7, |case, rng| {
        let m = rng.range_i64(1, 40);
        let k = rng.range_i64(1, 30);
        let n = rng.range_i64(1, 36);
        let lda = m + rng.range_i64(0, 4);
        let ldb = m + rng.range_i64(0, 4);
        let ldc = k + rng.range_i64(0, 4);
        let kernel = ops::matmul_padded(m, k, n, lda, ldb, ldc, 8, 0);
        let tile = [
            rng.range_i64(1, 14).min(m),
            rng.range_i64(1, 10).min(n),
            rng.range_i64(1, 9).min(k),
        ];
        check_bitwise(
            &kernel,
            TileBasis::rect(&tile),
            &format!("case {case}: matmul {m}x{k}x{n}"),
        );
    });
}
