//! Integration tests for the pluggable tiling-strategy layer.
//!
//! Three contracts the strategy race must keep:
//!
//! * **determinism** — [`pick_winner`]'s tie-keeps-default rule makes
//!   the recorded winner stable: the lattice incumbent keeps the slot
//!   unless a rival clears the upgrade margin, and re-racing the same
//!   rates re-picks the same winner.
//! * **differential** — strategies differ **only in blocking**: every
//!   strategy's proposed [`LevelPlan`] (and the parameter-free flat
//!   fallback) must produce bitwise-identical output on integer-valued
//!   data for all four Table-1 kernels at both dtypes. A single-ULP
//!   divergence means a strategy changed arithmetic, not tiling.
//! * **degradation** — a rival strategy that panics mid-race scores
//!   zero and the lattice incumbent keeps the race; the panic never
//!   unwinds through the caller.

use latticetile::cache::CacheSpec;
use latticetile::codegen::executor::{KernelBuffers, TiledExecutor};
use latticetile::codegen::{pick_winner, race_strategies_over, DType, GemmForm, MicroShape, Scalar};
use latticetile::coordinator::Planner;
use latticetile::domain::{ops, Kernel};
use latticetile::runtime::Registry;
use latticetile::tiling::{
    strategy_impl, LevelPlan, ShapeClass, StrategyChoice, StrategyKind, TileBasis, TiledSchedule,
    TilingStrategy,
};

/// L1 tile extents of a schedule in GEMM (rows, cols, red) loop space —
/// the basis row sums grouped per GEMM axis, as the planner derives them.
fn l1_of(gf: &GemmForm, sched: &TiledSchedule) -> (usize, usize, usize) {
    let b = sched.basis();
    let ext = |i: usize| -> usize {
        (0..b.dim())
            .map(|j| b.basis()[(i, j)].unsigned_abs() as usize)
            .sum::<usize>()
            .max(1)
    };
    let group = |axes: &[usize]| -> usize {
        axes.iter().map(|&t| ext(t)).product::<usize>().max(1)
    };
    (
        group(&gf.row_axes),
        group(&gf.col_axes),
        group(&gf.red_axes),
    )
}

/// Run `kernel` under every strategy's proposed macro blocking (plus the
/// flat fallback) at dtype `T` and demand bitwise equality with the
/// integer-filled scalar oracle.
fn check_strategies_bitwise<T: Scalar>(kernel: &Kernel, basis: TileBasis, label: &str) {
    let gf = GemmForm::of(kernel).expect("Table-1 kernels are GEMM-form");
    let sched = TiledSchedule::new(basis);
    let l1 = l1_of(&gf, &sched);
    let mut plans: Vec<(&'static str, LevelPlan)> = StrategyKind::RACED
        .iter()
        .map(|&kind| {
            (
                kind.name(),
                strategy_impl(kind).propose(
                    kernel,
                    (gf.m, gf.n, gf.k),
                    l1,
                    &CacheSpec::HASWELL_L2,
                    Some(&CacheSpec::HASWELL_L3_SLICE),
                    8,
                ),
            )
        })
        .collect();
    plans.push(("flat", LevelPlan::flat((8, 8, 8), 64, 64, 48)));
    let mut bufs = KernelBuffers::<T>::from_kernel(kernel);
    bufs.fill_ints(3, 0xBEEF ^ label.len() as u64);
    let want = bufs.reference();
    for (name, lp) in plans {
        let exec = TiledExecutor::new(sched.clone())
            .with_micro_shape(MicroShape::Mr8Nr4)
            .with_level_plan(lp);
        bufs.reset_output();
        exec.run(&mut bufs, kernel);
        assert_eq!(
            bufs.output(),
            want,
            "{label} ({}B elem): strategy {name} diverged bitwise — \
             a strategy may change blocking, never arithmetic",
            T::ELEM
        );
    }
}

fn check_strategies_bitwise_both(make: impl Fn(usize) -> Kernel, basis: TileBasis, label: &str) {
    check_strategies_bitwise::<f64>(&make(8), basis.clone(), label);
    check_strategies_bitwise::<f32>(&make(4), basis, label);
}

#[test]
fn all_strategies_are_bitwise_identical_on_matmul() {
    check_strategies_bitwise_both(
        |elem| ops::matmul(48, 32, 40, elem, 0),
        TileBasis::rect(&[16, 16, 16]),
        "matmul 48x32x40",
    );
}

#[test]
fn all_strategies_are_bitwise_identical_on_kronecker() {
    check_strategies_bitwise_both(
        |elem| ops::kronecker(5, 3, 7, 4, elem, 0),
        TileBasis::rect(&[2, 2, 4, 3]),
        "kron 5x3x7x4",
    );
}

#[test]
fn all_strategies_are_bitwise_identical_on_convolution() {
    check_strategies_bitwise_both(
        |elem| ops::convolution(100, elem, 0),
        TileBasis::rect(&[16]),
        "conv n=100",
    );
}

#[test]
fn all_strategies_are_bitwise_identical_on_scalar_product() {
    check_strategies_bitwise_both(
        |elem| ops::scalar_product(100, elem, 0),
        TileBasis::rect(&[16]),
        "dot n=100",
    );
}

#[test]
fn pick_winner_is_deterministic_and_ties_keep_the_incumbent() {
    use StrategyKind::*;
    // exact tie: the incumbent (first entry) keeps the slot
    let tied = [(Lattice, 10.0), (Oblivious, 10.0), (Latency, 10.0)];
    assert_eq!(pick_winner(&tied), Lattice);
    // within the 5% upgrade margin: still the incumbent — a rival must
    // *clearly* win to displace the recorded default
    let close = [(Lattice, 10.0), (Oblivious, 10.4), (Latency, 10.2)];
    assert_eq!(pick_winner(&close), Lattice);
    // a rival past the margin takes the slot, and re-running the same
    // rates re-picks the same winner (pure function of its input)
    let upset = [(Lattice, 10.0), (Oblivious, 11.0), (Latency, 10.1)];
    assert_eq!(pick_winner(&upset), Oblivious);
    assert_eq!(pick_winner(&upset), pick_winner(&upset));
}

#[test]
fn repeated_races_report_strategies_in_stable_incumbent_first_order() {
    let kernel = ops::matmul(32, 24, 28, 8, 0);
    for _ in 0..2 {
        let rates = latticetile::codegen::race_strategy_rates::<f64>(
            &kernel,
            MicroShape::Mr8Nr4,
            4,
            1,
        );
        assert_eq!(
            rates.iter().map(|&(k, _)| k).collect::<Vec<_>>(),
            StrategyKind::RACED.to_vec(),
            "race order (lattice-incumbent first) must be stable across runs"
        );
        assert!(rates.iter().all(|&(_, r)| r > 0.0));
    }
}

/// A rival that panics while proposing — the race must absorb it.
struct Panicker;

impl TilingStrategy for Panicker {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Latency
    }

    fn propose(
        &self,
        _kernel: &Kernel,
        _extents: (usize, usize, usize),
        _l1_tile: (usize, usize, usize),
        _l2: &CacheSpec,
        _l3: Option<&CacheSpec>,
        _sample_classes: usize,
    ) -> LevelPlan {
        panic!("injected strategy failure");
    }
}

#[test]
fn panicking_rival_scores_zero_and_the_lattice_incumbent_wins() {
    let kernel = ops::matmul(32, 24, 28, 8, 0);
    let strategies: [&dyn TilingStrategy; 2] = [&latticetile::tiling::Lattice, &Panicker];
    let rates = race_strategies_over::<f64>(&strategies, &kernel, MicroShape::Mr8Nr4, 4, 1);
    assert_eq!(rates.len(), 2);
    assert!(rates[0].1 > 0.0, "the incumbent must still measure");
    assert_eq!(
        rates[1],
        (StrategyKind::Latency, 0.0),
        "a panicking strategy scores zero instead of unwinding the race"
    );
    assert_eq!(pick_winner(&rates), StrategyKind::Lattice);
}

#[test]
fn planner_dispatches_and_names_the_recorded_or_overridden_strategy() {
    let spec = CacheSpec::HASWELL_L1D;
    let kernel = ops::matmul(64, 64, 64, 8, 0);

    // a fixed override bypasses the registry entirely
    let plan = Planner::new(spec)
        .with_strategy(StrategyChoice::Fixed(StrategyKind::Oblivious))
        .plan_kernel(&Registry::default(), &kernel);
    assert_eq!(plan.strategy, "oblivious");
    assert!(
        plan.describe().contains("strategy oblivious"),
        "describe() must name the dispatched strategy: {}",
        plan.describe()
    );

    // auto dispatch resolves the registry-recorded race winner…
    let reg = Registry::default();
    reg.set_strategy_for(
        DType::F64,
        "matmul",
        ShapeClass::of((64, 64, 64)),
        StrategyKind::Latency,
    );
    let plan = Planner::new(spec).plan_kernel(&reg, &kernel);
    assert_eq!(plan.strategy, "latency");

    // …and falls back to the lattice incumbent when no race has run
    let plan = Planner::new(spec).plan_kernel(&Registry::default(), &kernel);
    assert_eq!(plan.strategy, "lattice");
}
