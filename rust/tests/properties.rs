//! Property-based tests over the core invariants (hand-rolled driver —
//! see `latticetile::testutil`; proptest is unavailable offline).
//!
//! Each property is checked over dozens of pseudo-random cases with
//! deterministic seeds, so failures reproduce exactly.

use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::codegen::executor::{prototile_points, KernelBuffers, TiledExecutor};
use latticetile::codegen::{max_abs_diff, run_parallel, run_trace_only};
use latticetile::conflict::MissModel;
use latticetile::domain::order::Scanner;
use latticetile::domain::{ops, IterOrder};
use latticetile::lattice::{lll_reduce, norm2, IMat, Lattice};
use latticetile::testutil::{prop_check, Rng};
use latticetile::tiling::{TileBasis, TiledSchedule};

fn random_full_rank_2x2(rng: &mut Rng, max: i64) -> IMat {
    loop {
        let m = IMat::from_rows(&[
            &[
                rng.range_i64(-max, max) as i128,
                rng.range_i64(-max, max) as i128,
            ],
            &[
                rng.range_i64(-max, max) as i128,
                rng.range_i64(-max, max) as i128,
            ],
        ]);
        if m.det() != 0 {
            return m;
        }
    }
}

/// LLL preserves the lattice (same det, mutual membership) and never
/// lengthens the shortest basis vector.
#[test]
fn prop_lll_preserves_lattice_and_shortens() {
    prop_check(40, 0xA11CE, |case, rng| {
        let b = random_full_rank_2x2(rng, 40);
        let l = Lattice::from_basis(b.clone());
        let r = lll_reduce(&b);
        assert_eq!(r.det().abs(), b.det().abs(), "case {case}: det changed");
        let lr = Lattice::from_basis(r.clone());
        for j in 0..2 {
            assert!(l.contains(&r.col(j)), "case {case}: reduced vec not in L");
            assert!(lr.contains(&b.col(j)), "case {case}: original vec not in L'");
        }
        let orig_min = (0..2).map(|j| norm2(&b.col(j))).min().unwrap();
        let red_min = (0..2).map(|j| norm2(&r.col(j))).min().unwrap();
        assert!(red_min <= orig_min, "case {case}: LLL lengthened the basis");
    });
}

/// The congruence lattice membership matches the defining congruence for
/// random weights/moduli.
#[test]
fn prop_congruence_lattice_matches_definition() {
    prop_check(30, 0xBEEF, |case, rng| {
        let w = vec![
            rng.range_i64(1, 50) as i128,
            rng.range_i64(1, 200) as i128,
        ];
        let n = *rng.pick(&[4i128, 8, 16, 64, 512]);
        let l = Lattice::from_congruence(&w, n);
        for _ in 0..50 {
            let x = [rng.range_i64(-30, 30) as i128, rng.range_i64(-30, 30) as i128];
            let expect = (w[0] * x[0] + w[1] * x[1]).rem_euclid(n) == 0;
            assert_eq!(l.contains(&x), expect, "case {case}, x={x:?}");
        }
    });
}

/// Tiles partition the domain: every point visited exactly once, for
/// random (possibly skewed) tile bases.
#[test]
fn prop_tiled_schedule_is_a_partition() {
    prop_check(25, 0x7115, |case, rng| {
        // random 2-D basis with controlled skew
        let b = loop {
            let m = IMat::from_rows(&[
                &[
                    rng.range_i64(1, 6) as i128,
                    rng.range_i64(-3, 3) as i128,
                ],
                &[
                    rng.range_i64(-3, 3) as i128,
                    rng.range_i64(1, 6) as i128,
                ],
            ]);
            if m.det() != 0 {
                break m;
            }
        };
        let basis = TileBasis::from_cols(b);
        let extents = [rng.range_i64(5, 18), rng.range_i64(5, 18)];
        let s = TiledSchedule::new(basis);
        let mut seen = std::collections::HashSet::new();
        let mut count = 0u64;
        s.scan_points(&extents, &mut |x: &[i64]| {
            assert!(seen.insert(x.to_vec()), "case {case}: point visited twice");
            count += 1;
        });
        assert_eq!(
            count,
            (extents[0] * extents[1]) as u64,
            "case {case}: coverage"
        );
    });
}

/// The prototile always contains exactly |det| integer points.
#[test]
fn prop_prototile_volume() {
    prop_check(25, 0xD117, |case, rng| {
        let b = loop {
            let m = IMat::from_rows(&[
                &[rng.range_i64(1, 8) as i128, rng.range_i64(-4, 4) as i128],
                &[rng.range_i64(-4, 4) as i128, rng.range_i64(1, 8) as i128],
            ]);
            if m.det() != 0 {
                break m;
            }
        };
        let t = TileBasis::from_cols(b);
        let pts = prototile_points(&t);
        assert_eq!(pts.len() as i128, t.volume(), "case {case}");
    });
}

/// Keystone at scale: line-granular miss model == cache simulator on
/// random kernels, specs, and orders.
#[test]
fn prop_model_equals_sim_random() {
    prop_check(15, 0x5EED, |case, rng| {
        let m = rng.range_i64(3, 14);
        let k = rng.range_i64(3, 14);
        let n = rng.range_i64(3, 14);
        let lda = m + rng.range_i64(0, 4);
        let ldb = m + rng.range_i64(0, 4);
        let ldc = k + rng.range_i64(0, 4);
        let base = rng.range_i64(0, 8) as usize * 8;
        let kernel = ops::matmul_padded(m, k, n, lda, ldb, ldc, 8, base);
        let ways = *rng.pick(&[2usize, 4, 8]);
        let sets = *rng.pick(&[4usize, 16, 64]);
        let line = *rng.pick(&[8usize, 16, 64]);
        let spec = CacheSpec::new(sets * ways * line, line, ways, 1);
        let perm: Vec<usize> = match rng.range_usize(0, 2) {
            0 => vec![0, 1, 2],
            1 => vec![1, 2, 0],
            _ => vec![2, 0, 1],
        };
        let order = IterOrder::permuted(&perm);

        let model = MissModel::new(&kernel, &spec);
        let counts = model.exact(&order);
        let mut sim = CacheSim::new(spec, Policy::Lru);
        order.scan(kernel.extents(), |f| {
            for a in kernel.addrs_at(f) {
                sim.access(a);
            }
        });
        assert_eq!(
            counts.misses,
            sim.stats().misses(),
            "case {case}: kernel ({m},{k},{n}) lda={lda} spec={spec:?} perm={perm:?}"
        );
    });
}

/// Executors compute the right answer for random shapes/tiles/threads.
#[test]
fn prop_executors_numerically_correct() {
    prop_check(12, 0xFAB, |case, rng| {
        let m = rng.range_i64(8, 40);
        let k = rng.range_i64(8, 40);
        let n = rng.range_i64(8, 40);
        let kernel = ops::matmul(m, k, n, 8, 0);
        let b = loop {
            let mm = IMat::from_rows(&[
                &[
                    rng.range_i64(2, 9) as i128,
                    0,
                    rng.range_i64(-2, 2) as i128,
                ],
                &[0, rng.range_i64(2, 9) as i128, 0],
                &[
                    rng.range_i64(-2, 2) as i128,
                    0,
                    rng.range_i64(2, 9) as i128,
                ],
            ]);
            if mm.det() != 0 {
                break mm;
            }
        };
        let sched = TiledSchedule::new(TileBasis::from_cols(b));
        let exec = TiledExecutor::new(sched.clone());
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let want = bufs.reference();
        exec.run(&mut bufs, &kernel);
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "case {case}: serial tiled executor wrong"
        );
        let threads = rng.range_usize(1, 4);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        run_parallel(&mut bufs, &kernel, &sched, threads, 1);
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "case {case}: parallel executor wrong ({threads} threads)"
        );
    });
}

/// LRU reuse-distance law on the simulator: an address re-accessed after
/// touching `d` distinct other same-set lines hits iff `d < K`.
#[test]
fn prop_lru_distance_law() {
    prop_check(20, 0xCAFE, |case, rng| {
        let ways = *rng.pick(&[2usize, 4, 8]);
        let sets = 8usize;
        let line = 16usize;
        let spec = CacheSpec::new(sets * ways * line, line, ways, 1);
        let mut sim = CacheSim::new(spec, Policy::Lru);
        let set_stride = sets * line;
        sim.access(0);
        let d = rng.range_usize(0, ways + 2);
        for t in 1..=d {
            sim.access(t * set_stride);
        }
        let hit = sim.access(0).hit;
        assert_eq!(hit, d < ways, "case {case}: d={d} K={ways}");
    });
}

/// Miss counts are schedule-order invariants of the *set* of points only
/// when the cache is large enough to never evict: with an infinite-ish
/// cache every schedule yields exactly the cold-miss count.
#[test]
fn prop_big_cache_only_cold_misses() {
    prop_check(10, 0x1CE, |case, rng| {
        let m = rng.range_i64(4, 10);
        let k = rng.range_i64(4, 10);
        let n = rng.range_i64(4, 10);
        let kernel = ops::matmul(m, k, n, 8, 0);
        let spec = CacheSpec::new(1 << 22, 8, 8, 1); // 4 MiB, elem-granular
        let distinct_elems = (m * n + m * k + k * n) as u64;
        for perm in [[0usize, 1, 2], [2, 1, 0]] {
            let order = IterOrder::permuted(&perm);
            let mut sim = CacheSim::new(spec, Policy::Lru);
            run_trace_only(&kernel, &order, &mut sim);
            assert_eq!(
                sim.stats().misses(),
                distinct_elems,
                "case {case} perm {perm:?}"
            );
            assert_eq!(sim.stats().cold, distinct_elems);
        }
    });
}
