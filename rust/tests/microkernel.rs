//! Property tests pinning the packed / microkernel executor paths
//! against the kernel-semantic scalar oracle: random skewed bases,
//! non-multiple extents, padded layouts (`ops::matmul_padded`) — so
//! boundary clipping and packing offsets can never silently regress. The
//! two-level tests do the same for the macro-kernel: random `mc×kc×nc`
//! macro shapes that divide neither the L1 tile nor MR/NR, plus the
//! pack-once invariant. (Differential tests for the non-matmul Table-1
//! kernels live in `tests/kernels.rs`.)

use latticetile::codegen::executor::{
    max_abs_diff, run_macro, KernelBuffers, TiledExecutor,
};
use latticetile::codegen::{
    kernel_views, run_parallel, run_parallel_macro, GemmForm, MicroShape, PackedCols,
    PackedRows, MR, NR,
};
use latticetile::domain::ops;
use latticetile::lattice::IMat;
use latticetile::testutil::prop_check;
use latticetile::tiling::{LevelPlan, TileBasis, TiledSchedule};

fn check(kernel: &latticetile::domain::Kernel, basis: TileBasis, label: &str) {
    let sched = TiledSchedule::new(basis);
    let exec = TiledExecutor::new(sched.clone());
    let mut bufs = KernelBuffers::<f64>::from_kernel(kernel);
    let want = bufs.reference();
    exec.run(&mut bufs, kernel);
    assert!(
        max_abs_diff(&want, &bufs.output()) < 1e-9,
        "{label}: serial executor wrong"
    );
}

/// Rect pack + microkernel path: random shapes and paddings, tile sizes
/// deliberately not multiples of MR/NR (and sometimes larger than the
/// domain) so edge blocks appear inside and on every boundary.
#[test]
fn prop_packed_rect_matches_reference() {
    prop_check(20, 0x9ACC, |case, rng| {
        let m = rng.range_i64(1, 45);
        let k = rng.range_i64(1, 30);
        let n = rng.range_i64(1, 40);
        let lda = m + rng.range_i64(0, 5);
        let ldb = m + rng.range_i64(0, 5);
        let ldc = k + rng.range_i64(0, 5);
        let base = rng.range_i64(0, 16) as usize * 8;
        let kernel = ops::matmul_padded(m, k, n, lda, ldb, ldc, 8, base);
        let tile = [
            rng.range_i64(1, 2 * MR as i64).min(m.max(1)),
            rng.range_i64(1, 2 * NR as i64).min(n.max(1)),
            rng.range_i64(1, 12).min(k.max(1)),
        ];
        check(
            &kernel,
            TileBasis::rect(&tile),
            &format!("case {case}: rect {m}x{k}x{n} lda={lda} tile={tile:?}"),
        );
    });
}

/// Skewed panel-replay path (j decoupled): random (i, kk)-skews, padded
/// layouts, extents that never divide the tile.
#[test]
fn prop_panel_replay_matches_reference() {
    prop_check(20, 0x5EAD, |case, rng| {
        let m = rng.range_i64(6, 40);
        let k = rng.range_i64(6, 34);
        let n = rng.range_i64(6, 38);
        let lda = m + rng.range_i64(0, 4);
        let ldb = m + rng.range_i64(0, 4);
        let ldc = k + rng.range_i64(0, 4);
        let kernel = ops::matmul_padded(m, k, n, lda, ldb, ldc, 8, 0);
        let basis = loop {
            let b = IMat::from_rows(&[
                &[
                    rng.range_i64(2, 9) as i128,
                    0,
                    rng.range_i64(-3, 3) as i128,
                ],
                &[0, rng.range_i64(1, 9) as i128, 0],
                &[
                    rng.range_i64(-3, 3) as i128,
                    0,
                    rng.range_i64(2, 9) as i128,
                ],
            ]);
            // require a genuine (i, kk) skew — a diagonal draw would be
            // rect and take the pack path instead of panel replay
            if b.det() != 0 && (b[(0, 2)] != 0 || b[(2, 0)] != 0) {
                break b;
            }
        };
        let tile = TileBasis::from_cols(basis);
        let exec = TiledExecutor::new(TiledSchedule::new(tile.clone()));
        assert!(
            exec.replay(&kernel).panel_replay(),
            "case {case}: decoupled-j basis must take the panel path"
        );
        check(&kernel, tile, &format!("case {case}: skewed {m}x{k}x{n}"));
    });
}

/// Fully coupled bases (no decoupled j) must fall back to scalar replay
/// and still be exact.
#[test]
fn prop_coupled_fallback_matches_reference() {
    prop_check(12, 0xC0DE, |case, rng| {
        let m = rng.range_i64(5, 24);
        let k = rng.range_i64(5, 20);
        let n = rng.range_i64(5, 22);
        let kernel = ops::matmul(m, k, n, 8, 0);
        let basis = loop {
            let b = IMat::from_rows(&[
                &[rng.range_i64(2, 6) as i128, rng.range_i64(0, 2) as i128, 0],
                &[rng.range_i64(1, 2) as i128, rng.range_i64(2, 6) as i128, 0],
                &[0, 0, rng.range_i64(2, 6) as i128],
            ]);
            if b.det() != 0 {
                break b;
            }
        };
        let tile = TileBasis::from_cols(basis);
        let exec = TiledExecutor::new(TiledSchedule::new(tile.clone()));
        assert!(
            !exec.replay(&kernel).panel_replay(),
            "case {case}: coupled-j basis must fall back"
        );
        check(&kernel, tile, &format!("case {case}: coupled {m}x{k}x{n}"));
    });
}

/// The parallel executor shares the engine: rect and skewed tiles under
/// 1–4 threads must match the oracle, including non-multiple extents.
#[test]
fn prop_parallel_engine_matches_reference() {
    prop_check(10, 0xFA57, |case, rng| {
        let m = rng.range_i64(8, 36);
        let k = rng.range_i64(8, 30);
        let n = rng.range_i64(8, 33);
        let kernel = ops::matmul(m, k, n, 8, 0);
        let threads = rng.range_usize(1, 4);
        // rect
        let tile = [
            rng.range_i64(2, 12).min(m),
            rng.range_i64(2, 12).min(n),
            rng.range_i64(2, 12).min(k),
        ];
        let sched = TiledSchedule::new(TileBasis::rect(&tile));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let want = bufs.reference();
        run_parallel(&mut bufs, &kernel, &sched, threads, 1);
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "case {case}: parallel rect ({threads} threads)"
        );
        // skewed, j decoupled
        let basis = loop {
            let b = IMat::from_rows(&[
                &[rng.range_i64(2, 7) as i128, 0, rng.range_i64(-2, 2) as i128],
                &[0, rng.range_i64(2, 7) as i128, 0],
                &[rng.range_i64(-2, 2) as i128, 0, rng.range_i64(2, 7) as i128],
            ]);
            if b.det() != 0 {
                break b;
            }
        };
        let sched = TiledSchedule::new(TileBasis::from_cols(basis));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        run_parallel(&mut bufs, &kernel, &sched, threads, 1);
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "case {case}: parallel skewed ({threads} threads)"
        );
    });
}

/// Two-level macro-kernel: random macro shapes — `mc/kc/nc` deliberately
/// not multiples of the L1 tile or of MR/NR — over padded layouts with
/// non-zero arena bases, against the naive oracle; both register-tile
/// widths.
#[test]
fn prop_macro_kernel_matches_reference() {
    prop_check(20, 0x2CE1, |case, rng| {
        let m = rng.range_i64(1, 50);
        let k = rng.range_i64(1, 40);
        let n = rng.range_i64(1, 45);
        let lda = m + rng.range_i64(0, 5);
        let ldb = m + rng.range_i64(0, 5);
        let ldc = k + rng.range_i64(0, 5);
        let base = rng.range_i64(0, 8) as usize * 8;
        let kernel = ops::matmul_padded(m, k, n, lda, ldb, ldc, 8, base);
        let lp = LevelPlan {
            l1_tile: (
                rng.range_usize(1, 20),
                rng.range_usize(1, 16),
                rng.range_usize(1, 12),
            ),
            mc: rng.range_usize(1, 24),
            kc: rng.range_usize(1, 20),
            nc: rng.range_usize(1, 22),
            // raw (possibly unaligned, possibly tiny) super-band extents:
            // the executor aligns them down to mc/nc multiples itself
            m3: rng.range_usize(1, 60),
            n3: rng.range_usize(1, 55),
        };
        let tile = [
            (lp.l1_tile.0 as i64).min(m),
            (lp.l1_tile.1 as i64).min(n),
            (lp.l1_tile.2 as i64).min(k),
        ];
        let micro = *rng.pick(&[MicroShape::Mr8Nr4, MicroShape::Mr8Nr6]);
        let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&tile)))
            .with_level_plan(lp)
            .with_micro_shape(micro);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let want = bufs.reference();
        exec.run(&mut bufs, &kernel);
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "case {case}: macro {m}x{k}x{n} lda={lda} lp={lp:?} micro={micro:?}"
        );
    });
}

/// The pack-amortization invariant the macro-kernel exists for: each row
/// macro block is packed exactly once per reduction slice, each column
/// band once per (slice, band) — counted, not assumed.
#[test]
fn macro_kernel_packs_each_row_block_exactly_once() {
    let (m, k, n) = (37usize, 29, 31);
    let kernel = ops::matmul(m as i64, k as i64, n as i64, 8, 0);
    // a flat plan (single super-band): the classic per-slice pack counts
    let lp = LevelPlan::flat((8, 8, 8), 16, 12, 10);
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let want = bufs.reference();
    let gf = GemmForm::of(&kernel).unwrap();
    let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
    let mut pr = PackedRows::<f64>::new();
    let mut pc = PackedCols::<f64>::new();
    run_macro(
        &mut bufs.arena,
        &plan,
        &lp,
        MicroShape::Mr8Nr4,
        &mut pr,
        &mut pc,
    );
    assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    let kslices = k.div_ceil(lp.kc) as u64;
    let rblocks = m.div_ceil(lp.mc) as u64;
    let cbands = n.div_ceil(lp.nc) as u64;
    assert_eq!(
        pr.pack_count(),
        kslices * rblocks,
        "row pack count per macro block must be exactly 1"
    );
    assert_eq!(
        pc.pack_count(),
        kslices * cbands,
        "column pack count per (slice, band) must be exactly 1"
    );
}

/// The macro-kernel parallel path: random macro shapes, whole-nc column
/// bands per worker over the shared packed rows, 1–4 threads.
#[test]
fn prop_parallel_macro_matches_reference() {
    prop_check(10, 0xBA2D, |case, rng| {
        let m = rng.range_i64(8, 40);
        let k = rng.range_i64(8, 30);
        let n = rng.range_i64(8, 36);
        let kernel = ops::matmul(m, k, n, 8, 0);
        let threads = rng.range_usize(1, 4);
        let lp = LevelPlan {
            l1_tile: (
                rng.range_usize(4, 12),
                rng.range_usize(4, 12),
                rng.range_usize(4, 12),
            ),
            mc: rng.range_usize(4, 20),
            kc: rng.range_usize(4, 16),
            nc: rng.range_usize(4, 18),
            // raw super-band extents — normalized to mc/nc multiples by
            // the scheduler, frequently yielding several claimable bands
            m3: rng.range_usize(4, 48),
            n3: rng.range_usize(4, 44),
        };
        let sched = TiledSchedule::new(TileBasis::rect(&[
            (lp.l1_tile.0 as i64).min(m),
            (lp.l1_tile.1 as i64).min(n),
            (lp.l1_tile.2 as i64).min(k),
        ]));
        let micro = *rng.pick(&[MicroShape::Mr8Nr4, MicroShape::Mr8Nr6]);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let want = bufs.reference();
        run_parallel_macro(&mut bufs, &kernel, &sched, threads, Some(lp), micro);
        assert!(
            max_abs_diff(&want, &bufs.output()) < 1e-9,
            "case {case}: parallel macro {m}x{k}x{n} ({threads} threads) lp={lp:?}"
        );
    });
}

/// L3 super-band parallel edge cases through the public API: heavy
/// oversubscription (threads ≫ bands), super-band extents that divide
/// neither m nor n, and the single-band degeneration back to the flat
/// schedule — all against the oracle, with the schedule counters pinned.
#[test]
fn parallel_super_band_edge_cases() {
    use latticetile::codegen::run_parallel_macro_stats;
    let kernel = ops::matmul(41, 13, 29, 8, 0);
    let sched = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
    // 41 rows / m3=16 → 3 row bands; 29 cols / n3=12 → 3 column bands
    // (neither extent divides)
    let lp = LevelPlan {
        l1_tile: (8, 8, 8),
        mc: 8,
        kc: 5,
        nc: 6,
        m3: 16,
        n3: 12,
    };
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let want = bufs.reference();
    let stats =
        run_parallel_macro_stats(&mut bufs, &kernel, &sched, 64, Some(lp), MicroShape::Mr8Nr4);
    assert_eq!(stats.super_bands, 9);
    assert_eq!(stats.workers, 9, "threads=64 must clamp to the band count");
    assert_eq!(stats.row_slice_packs, 9 * 3, "3 kc slices per band");
    assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    // single-band degeneration: a flat plan is the old behaviour —
    // bitwise equal to the serial macro engine on the same shape
    let flat = LevelPlan::flat((8, 8, 8), 8, 5, 6);
    let mut par = KernelBuffers::<f64>::from_kernel(&kernel);
    par.fill_ints(2, 0xE5);
    let mut ser = par.clone();
    let want2 = par.reference();
    let stats =
        run_parallel_macro_stats(&mut par, &kernel, &sched, 8, Some(flat), MicroShape::Mr8Nr4);
    assert_eq!((stats.super_bands, stats.workers), (1, 1));
    let gf = GemmForm::of(&kernel).unwrap();
    let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
    run_macro(
        &mut ser.arena,
        &plan,
        &flat,
        MicroShape::Mr8Nr4,
        &mut PackedRows::new(),
        &mut PackedCols::new(),
    );
    assert_eq!(par.output(), want2);
    assert_eq!(ser.output(), par.output());
}

/// Exact MR/NR boundary shapes: one-off extents around the register-tile
/// sizes where an off-by-one in panel clipping would bite first.
#[test]
fn microkernel_boundary_shapes() {
    let mr = MR as i64;
    let nr = NR as i64;
    for m in [1, mr - 1, mr, mr + 1, 2 * mr] {
        for n in [1, nr - 1, nr, nr + 1, 3 * nr] {
            for k in [1, 2, 7] {
                let kernel = ops::matmul(m, k, n, 8, 0);
                check(
                    &kernel,
                    TileBasis::rect(&[mr.min(m), nr.min(n), k]),
                    &format!("boundary {m}x{k}x{n}"),
                );
            }
        }
    }
}
