//! End-to-end integration: plan → artifact → PJRT execution → numerics,
//! and the full simulated-miss chain plan → schedule → simulator
//! (DESIGN.md E5/E11 in test form). Artifact-dependent tests self-skip if
//! `make artifacts` has not run.

use std::path::PathBuf;
use std::time::Duration;

use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::codegen::run_trace_only;
use latticetile::codegen::DType;
use latticetile::coordinator::{Backend, Planner, Service, ServiceConfig};
use latticetile::domain::{ops, IterOrder};
use latticetile::experiments::fig4;
use latticetile::runtime::{Engine, Registry};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.tsv").exists()
}

/// The full model chain: the hybrid plan must beat the naive order on
/// simulated Haswell misses at every benchmark size.
#[test]
fn planned_schedule_beats_naive_at_all_sizes() {
    for n in [96i64, 128, 192, 256] {
        let kernel = ops::matmul(n, n, n, 8, 0);
        let (name, plan) = fig4::hybrid_plan_for(n, &CacheSpec::HASWELL_L1D);
        let mut naive = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru).without_classification();
        run_trace_only(&kernel, &IterOrder::lex(3), &mut naive);
        let mut tiled = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru).without_classification();
        run_trace_only(&kernel, &plan, &mut tiled);
        assert!(
            tiled.stats().misses() * 2 < naive.stats().misses(),
            "n={n} plan={name}: {} vs naive {}",
            tiled.stats().misses(),
            naive.stats().misses()
        );
    }
}

/// All shipped kernel variants produce matching numerics through PJRT.
#[test]
fn all_pallas_variants_match_reference_artifact() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let reg = Registry::load(&artifacts_dir()).unwrap();
    let mut engine = Engine::new(reg).unwrap();
    let variants: Vec<(String, usize, usize, usize)> = engine
        .registry()
        .artifacts()
        .iter()
        .filter(|a| {
            a.kind == latticetile::runtime::ArtifactKind::PallasTiledMatmul && a.m <= 256
        })
        .map(|a| (a.name.clone(), a.m, a.k, a.n))
        .collect();
    assert!(variants.len() >= 3, "expected several shipped variants");
    for (name, m, k, n) in variants {
        let mut s = 0xABCDEFu64;
        let mut rnd = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s % 1000) as f32 / 1000.0) - 0.5
        };
        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let got = engine.run_matmul(&name, &x, &y).unwrap();
        // compare against the jnp reference artifact for the same shape
        let ref_name = format!("matmul_ref_{m}x{k}x{n}");
        let want = engine.run_matmul(&ref_name, &x, &y).unwrap();
        let maxd = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(maxd < 1e-3, "{name} deviates from jnp ref by {maxd}");
    }
}

/// Coordinator round trip under concurrent submission, with batching.
#[test]
fn coordinator_serves_burst_correctly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (m, k, n) = (128usize, 128, 128);
    let y: Vec<f32> = (0..k * n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let svc = Service::start(
        &artifacts_dir(),
        y.clone(),
        ServiceConfig {
            m,
            k,
            n,
            batch_window: Duration::from_millis(1),
            spec: CacheSpec::HASWELL_L1D,
            backend: Backend::Pjrt,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let jobs = 12usize;
    let xs: Vec<Vec<f32>> = (0..jobs)
        .map(|j| {
            (0..m * k)
                .map(|i| (((i + j * 31) % 13) as f32 - 6.0) / 6.0)
                .collect()
        })
        .collect();
    let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
    for (idx, rx) in rxs.into_iter().enumerate() {
        let got = rx.recv().unwrap();
        // spot-check one output element exactly
        let mut want0 = 0f32;
        for kk in 0..k {
            want0 += xs[idx][kk] * y[kk * n];
        }
        assert!(
            (got[0] - want0).abs() < 1e-2,
            "job {idx}: {} vs {}",
            got[0],
            want0
        );
    }
    let (metrics, _) = svc.stop();
    assert_eq!(metrics.jobs, jobs as u64);
    assert!(metrics.batches <= jobs as u64);
}

/// Planner resolves every serveable shape to a real artifact.
#[test]
fn planner_resolves_all_shipped_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let reg = Registry::load(&artifacts_dir()).unwrap();
    let planner = Planner::new(CacheSpec::HASWELL_L1D);
    let shapes: Vec<(usize, usize, usize)> = reg
        .artifacts()
        .iter()
        .filter(|a| a.kind == latticetile::runtime::ArtifactKind::PallasTiledMatmul)
        .map(|a| (a.m, a.k, a.n))
        .collect();
    for (m, k, n) in shapes {
        let p = planner.plan(&reg, m, k, n, DType::F32);
        assert!(
            reg.by_name(&p.artifact).is_some(),
            "plan for {m}x{k}x{n} resolved to missing artifact {}",
            p.artifact
        );
    }
}

/// CLI smoke tests: every subcommand runs and produces plausible output.
#[test]
fn cli_subcommands_smoke() {
    let bin = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("release")
        .join("latticetile");
    if !bin.exists() {
        eprintln!("skipping: build the release binary first");
        return;
    }
    let run = |args: &[&str]| -> String {
        let out = std::process::Command::new(&bin)
            .args(args)
            .output()
            .expect("spawn latticetile");
        assert!(
            out.status.success(),
            "latticetile {args:?} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let analyze = run(&["analyze", "--n", "64"]);
    assert!(analyze.contains("L(C,φ) det"));
    let plan = run(&["plan", "--n", "64"]);
    assert!(plan.contains("rank"));
    assert!(plan.contains("rect"));
    // dtype-aware planning: the f32 plan line must report an f32-wide
    // register tile (8x8 or 8x12), the f64 line an f64 one
    let plan32 = run(&["plan", "--n", "64", "--dtype", "f32"]);
    assert!(plan32.contains("/f32"), "{plan32}");
    let help = run(&["help"]);
    assert!(help.contains("USAGE"));
    assert!(help.contains("--dtype"), "usage must document --dtype");
    assert!(
        help.contains("--deadline-ms") && help.contains("--inject-faults"),
        "usage must document the robustness flags"
    );
}

/// The native f32 serve backend works end to end with no artifacts at
/// all — the packed macro-kernel is the serving engine.
#[test]
fn native_serve_backend_end_to_end() {
    let (m, k, n) = (64usize, 48, 56);
    let y: Vec<f32> = (0..k * n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let svc = Service::start(
        std::path::Path::new("no-artifacts-anywhere"),
        y.clone(),
        ServiceConfig {
            m,
            k,
            n,
            batch_window: Duration::from_millis(1),
            spec: CacheSpec::HASWELL_L1D,
            backend: Backend::Native,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    assert_eq!(svc.plan().dtype, DType::F32);
    let jobs = 6usize;
    let xs: Vec<Vec<f32>> = (0..jobs)
        .map(|j| {
            (0..m * k)
                .map(|i| (((i + j * 31) % 13) as f32 - 6.0) / 6.0)
                .collect()
        })
        .collect();
    let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
    for (idx, rx) in rxs.into_iter().enumerate() {
        let got = rx.recv().unwrap();
        // full-row check against an exact f64 accumulation oracle
        for j in 0..n {
            let mut want = 0f64;
            for kk in 0..k {
                want += (xs[idx][kk] as f64) * (y[kk * n + j] as f64);
            }
            assert!(
                (got[j] as f64 - want).abs() < 1e-3,
                "job {idx} col {j}: {} vs {}",
                got[j],
                want
            );
        }
    }
    let (metrics, _) = svc.stop();
    assert_eq!(metrics.jobs, jobs as u64);
}
