//! Chaos suite for the fault-tolerant serving runtime (run with
//! `cargo test --features fault-injection`).
//!
//! Each test arms a deterministic fault schedule
//! ([`Faults::seeded`] — seeded xorshift, no wall-clock dependence) and
//! drives the public serving API under it. The invariants are the
//! failure model's containment contract:
//!
//! * **No receiver ever hangs** — every accepted job resolves with
//!   `Ok(output)` or a typed [`JobError`] within the drain window.
//! * **Survivors stay correct** — any `Ok` result matches the row-major
//!   oracle exactly as in the fault-free tests.
//! * **Metrics account exactly once** — `jobs` = accepted, `errors` =
//!   panicked + backend-failed + stopped, `timeouts` = deadline-shed,
//!   `served()` = the rest.
//! * **Resident packs survive respawns** — the prepacked weight panels
//!   are never rebuilt by a worker restart.

#![cfg(feature = "fault-injection")]

use std::path::Path;
use std::time::Duration;

use latticetile::coordinator::{
    Backend, FaultMode, FaultPoint, Faults, JobError, Service, ServiceConfig,
};

fn rowmajor_matmul(m: usize, k: usize, n: usize, x: &[f32], y: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let xv = x[i * k + kk];
            for j in 0..n {
                out[i * n + j] += xv * y[kk * n + j];
            }
        }
    }
    out
}

fn xorshift_f32(seed: u64) -> impl FnMut() -> f32 {
    let mut s = seed | 1;
    move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        ((s % 1000) as f32 / 1000.0) - 0.5
    }
}

#[derive(Default)]
struct Outcomes {
    ok: usize,
    panicked: usize,
    backend: usize,
    deadline: usize,
    stopped: usize,
}

/// Drive `jobs` submissions through a fault-armed native service and
/// classify every resolution; panics if any receiver hangs past 10s.
fn drive(
    m: usize,
    k: usize,
    n: usize,
    y: &[f32],
    cfg: ServiceConfig,
    jobs: usize,
    seed: u64,
) -> (Outcomes, latticetile::coordinator::Metrics) {
    let svc = Service::start(Path::new("no-artifacts"), y.to_vec(), cfg)
        .expect("chaos service must start");
    let client = svc.client();
    let mut rnd = xorshift_f32(seed);
    let mut accepted: Vec<(Vec<f32>, _)> = Vec::new();
    for _ in 0..jobs {
        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
        // bounded retry outlasts injected QueueAccept rejections with
        // overwhelming probability; a final rejection is just "not
        // accepted", never a hang
        if let Ok(rx) = client.submit_with_retry(x.clone(), 16, Duration::from_micros(50)) {
            accepted.push((x, rx));
        }
    }
    let mut out = Outcomes::default();
    for (i, (x, rx)) in accepted.iter().enumerate() {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Some(Ok(got)) => {
                let want = rowmajor_matmul(m, k, n, x, y);
                let maxd = got
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(maxd < 1e-3, "job {i}: surviving result off by {maxd}");
                out.ok += 1;
            }
            Some(Err(JobError::WorkerPanicked { .. })) => out.panicked += 1,
            Some(Err(JobError::Backend { .. })) => out.backend += 1,
            Some(Err(JobError::DeadlineExceeded { .. })) => out.deadline += 1,
            Some(Err(JobError::Stopped)) => out.stopped += 1,
            None => panic!("job {i}: receiver hung under chaos — containment broken"),
        }
    }
    let (metrics, _) = svc.stop();
    assert_eq!(
        metrics.jobs as usize,
        accepted.len(),
        "every accepted job accounts exactly once"
    );
    assert_eq!(
        metrics.errors as usize,
        out.panicked + out.backend + out.stopped,
        "errors = panicked + backend + stopped"
    );
    assert_eq!(metrics.timeouts as usize, out.deadline, "timeouts = deadline-shed");
    assert_eq!(metrics.served() as usize, out.ok, "served = ok resolutions");
    assert!(!metrics.worker_poisoned, "the supervisor must keep the worker joinable");
    (out, metrics)
}

fn base_cfg(m: usize, k: usize, n: usize, faults: Faults) -> ServiceConfig {
    ServiceConfig {
        m,
        k,
        n,
        batch_window: Duration::from_millis(2),
        max_batch: 4,
        backend: Backend::Native,
        faults,
        ..ServiceConfig::default()
    }
}

#[test]
fn chaos_sweep_every_fault_point_resolves_and_accounts() {
    let (m, k, n) = (16usize, 12, 20);
    let mut rnd = xorshift_f32(0xC4A05);
    let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
    let schedule: [(FaultPoint, FaultMode, u64, u64); 5] = [
        (FaultPoint::BatchCompute, FaultMode::Panic, 1, 3),
        (FaultPoint::BatchCompute, FaultMode::Error, 1, 3),
        (FaultPoint::Pack, FaultMode::Panic, 1, 4),
        (FaultPoint::QueueAccept, FaultMode::Error, 1, 4),
        (FaultPoint::Plan, FaultMode::Error, 1, 1),
    ];
    for (i, (point, mode, num, den)) in schedule.into_iter().enumerate() {
        let faults = Faults::seeded(0x5EED0 + i as u64).fail(point, mode, num, den).build();
        let (out, metrics) = drive(
            m,
            k,
            n,
            &y,
            base_cfg(m, k, n, faults),
            32,
            0xD01 + i as u64,
        );
        println!(
            "{point:?}/{mode:?} {num}/{den}: ok={} panicked={} backend={} \
             restarts={} retries={}",
            out.ok, out.panicked, out.backend, metrics.worker_restarts, metrics.retries
        );
        match point {
            // plan faults hit only the two startup plans: full fallback,
            // zero serve-time casualties
            FaultPoint::Plan => {
                assert_eq!(metrics.fallback_plans, 2);
                assert_eq!(out.ok as u64, metrics.jobs);
            }
            // admission faults reject at the door; accepted jobs all serve
            FaultPoint::QueueAccept => {
                assert_eq!(out.ok as u64, metrics.jobs);
                assert!(metrics.retries > 0, "retry backoff must have engaged");
            }
            // compute/pack faults cost jobs but the ladder and the
            // supervisor keep the service alive and serving
            _ => assert!(out.ok > 0, "{point:?}: chaos must not kill the service"),
        }
    }
}

#[test]
fn chaos_respawn_preserves_resident_packed_panels() {
    let (m, k, n) = (16usize, 12, 20);
    let mut rnd = xorshift_f32(0x9E5B);
    let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
    // panic often enough that several lone-job double failures (and
    // therefore escalations to a worker respawn) happen across 32 jobs;
    // max_batch 1 keeps the check sequence independent of batch timing
    let faults = Faults::seeded(0xBEE)
        .fail(FaultPoint::BatchCompute, FaultMode::Panic, 1, 2)
        .build();
    let cfg = ServiceConfig {
        max_batch: 1,
        ..base_cfg(m, k, n, faults)
    };
    let (out, chaotic) = drive(m, k, n, &y, cfg, 32, 0xF00D);
    assert!(chaotic.worker_restarts >= 1, "the schedule must force a respawn");
    assert!(out.panicked >= 1);
    // pack discipline across respawns: identical resident pack count to
    // a fault-free service of the same shape — the supervisor reuses the
    // startup-prepacked weight panels, it never rebuilds them
    let clean_cfg = ServiceConfig {
        max_batch: 1,
        ..base_cfg(m, k, n, Faults::none())
    };
    let (_, clean) = drive(m, k, n, &y, clean_cfg, 4, 0xF00E);
    assert_eq!(clean.worker_restarts, 0);
    assert!(clean.resident_packs > 0);
    assert_eq!(chaotic.resident_packs, clean.resident_packs);
}

#[test]
fn chaos_pack_faults_contained_under_parallel_scheduler() {
    // the pipelined parallel serve path: with threads > 1 a wide batch
    // routes through the super-band scheduler, whose workers and
    // companion pack threads re-enter the worker's fault scope — an
    // armed Pack panic now unwinds *inside* a spawned thread, propagates
    // at scope join, and must still be contained by the supervisor: no
    // receiver hangs, survivors are correct, accounting is exact, and
    // the service keeps serving
    let (m, k, n) = (16usize, 24, 256);
    let mut rnd = xorshift_f32(0x7A11E1);
    let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
    let faults = Faults::seeded(0x9ACC5)
        .fail(FaultPoint::Pack, FaultMode::Panic, 1, 5)
        .build();
    let cfg = ServiceConfig {
        threads: 4,
        max_batch: 8,
        ..base_cfg(m, k, n, faults)
    };
    let (out, metrics) = drive(m, k, n, &y, cfg, 32, 0x7A11E2);
    println!(
        "parallel pack chaos: ok={} panicked={} restarts={}",
        out.ok, out.panicked, metrics.worker_restarts
    );
    assert!(out.ok > 0, "chaos must not kill the parallel service");
    assert!(
        out.panicked > 0 || metrics.worker_restarts > 0 || out.backend > 0,
        "the armed Pack schedule must have cost something"
    );
}

#[test]
fn chaos_plan_faults_degrade_to_the_flat_fallback_strategy() {
    // both startup plans erroring must not leave the strategy race's
    // recorded winner in charge: the served plan is the parameter-free
    // flat fallback and the metrics name it, so an operator can tell a
    // degraded planner from a raced winner at a glance
    let (m, k, n) = (16usize, 12, 20);
    let mut rnd = xorshift_f32(0x57A7);
    let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
    let faults = Faults::seeded(0x57A8)
        .fail(FaultPoint::Plan, FaultMode::Error, 1, 1)
        .build();
    let (out, metrics) = drive(m, k, n, &y, base_cfg(m, k, n, faults), 16, 0x57A9);
    assert_eq!(metrics.fallback_plans, 2);
    assert_eq!(metrics.plan_strategy, "flat-fallback");
    assert_eq!(out.ok as u64, metrics.jobs, "the degraded plan still serves");
    assert!(
        metrics.report(Duration::from_secs(1)).contains("plan-strategy=flat-fallback"),
        "the report must surface the degraded strategy"
    );
    // a fault-free start records a real raced strategy instead
    let (_, clean) = drive(m, k, n, &y, base_cfg(m, k, n, Faults::none()), 4, 0x57AA);
    assert!(
        ["lattice", "oblivious", "latency"].contains(&clean.plan_strategy.as_str()),
        "fault-free serving must name the raced winner, got {:?}",
        clean.plan_strategy
    );
}

#[test]
fn chaos_kitchen_sink_multi_point_with_deadline() {
    // every fault point armed at once, a tight deadline, and a burst of
    // jobs: the union of all degraded outcomes still accounts exactly and
    // leaves no receiver hanging
    let (m, k, n) = (24usize, 18, 30);
    let mut rnd = xorshift_f32(0x51C8);
    let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
    let faults = Faults::seeded(0xA11F4)
        .fail(FaultPoint::BatchCompute, FaultMode::Error, 1, 6)
        .fail(FaultPoint::Pack, FaultMode::Panic, 1, 8)
        .fail(FaultPoint::QueueAccept, FaultMode::Error, 1, 6)
        .fail(FaultPoint::Plan, FaultMode::Error, 1, 2)
        .build();
    let cfg = ServiceConfig {
        deadline: Some(Duration::from_millis(250)),
        ..base_cfg(m, k, n, faults)
    };
    let (out, metrics) = drive(m, k, n, &y, cfg, 48, 0xCAFE);
    println!(
        "kitchen sink: ok={} panicked={} backend={} deadline={} stopped={} \
         restarts={} retries={} fallback-plans={}",
        out.ok,
        out.panicked,
        out.backend,
        out.deadline,
        out.stopped,
        metrics.worker_restarts,
        metrics.retries,
        metrics.fallback_plans
    );
    assert!(out.ok > 0, "some jobs must survive the combined chaos");
    let report = metrics.report(Duration::from_secs(1));
    assert!(report.contains("served="), "{report}");
    assert!(report.contains("restarts="), "{report}");
}
