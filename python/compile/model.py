"""Layer 2: the JAX compute graph around the Pallas kernel.

The "model" for this paper is the tiled matmul itself plus the padding
logic that maps arbitrary problem sizes onto the kernel's block grid —
the same role the paper's generated loop bounds (CLooG) play around its
tile loops. Lowered once by aot.py; never imported at runtime.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.tiled_matmul import tiled_matmul


def _round_up(v: int, b: int) -> int:
    return (v + b - 1) // b * b


def matmul(x, y, *, bm: int = 64, bk: int = 64, bn: int = 64):
    """Dense f32 matmul via the Pallas kernel, padding to block multiples.

    Zero-padding is exact for matmul (padded rows/cols contribute zeros),
    mirroring the paper's padded-dimension handling (§2.1.1 index maps
    with padded physical dims).
    """
    m, k = x.shape
    _, n = y.shape
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = tiled_matmul(xp, yp, bm=bm, bk=bk, bn=bn)
    return out[:m, :n]


def matmul_ref(x, y):
    """The pure-jnp reference graph (lowered alongside for cross-checks)."""
    return ref.matmul(x, y)


def batched_matmul(xs, y, *, bm: int = 64, bk: int = 64, bn: int = 64):
    """Serve-path variant: a batch of left operands against one right
    operand, vmapped over the leading axis — what the coordinator's
    batcher dispatches as a single PJRT execution."""
    return jax.vmap(lambda x: matmul(x, y, bm=bm, bk=bk, bn=bn))(xs)
