"""AOT compile path: lower the L2 model to HLO **text** artifacts.

HLO text — not ``lowered.compile()`` and not serialized ``HloModuleProto``
— is the interchange format: jax ≥ 0.5 emits protos with 64-bit
instruction ids which the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once by ``make artifacts``:

    python -m compile.aot --out-dir ../artifacts

Emits one ``.hlo.txt`` per kernel variant plus ``manifest.json`` describing
shapes, block sizes, VMEM footprints and MXU-utilization estimates — the
registry the Rust runtime loads.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.tiled_matmul import mxu_utilization_estimate, vmem_footprint_bytes

# Kernel variants shipped to the Rust runtime. The L3 planner picks among
# these by mapping its lattice-model tile choice to the nearest block
# shape (DESIGN.md §Hardware-Adaptation).
#
# (m, k, n, bm, bk, bn)
VARIANTS = [
    (256, 256, 256, 64, 64, 64),
    (256, 256, 256, 128, 128, 128),
    (256, 256, 256, 32, 32, 32),
    (512, 512, 512, 128, 128, 128),
    (128, 128, 128, 64, 64, 64),
]

# Batched serve-path variants: (batch, m, k, n, bm, bk, bn)
BATCHED_VARIANTS = [
    (8, 128, 128, 128, 64, 64, 64),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning round trip)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(m, k, n, bm, bk, bn):
    fn = functools.partial(model.matmul, bm=bm, bk=bk, bn=bn)
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    y = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return jax.jit(fn).lower(x, y)


def lower_ref(m, k, n):
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    y = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return jax.jit(model.matmul_ref).lower(x, y)


def lower_batched(b, m, k, n, bm, bk, bn):
    fn = functools.partial(model.batched_matmul, bm=bm, bk=bk, bn=bn)
    xs = jax.ShapeDtypeStruct((b, m, k), jnp.float32)
    y = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return jax.jit(fn).lower(xs, y)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file mode")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"artifacts": []}

    for m, k, n, bm, bk, bn in VARIANTS:
        name = f"matmul_{m}x{k}x{n}_b{bm}x{bk}x{bn}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lower_variant(m, k, n, bm, bk, bn))
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": os.path.basename(path),
                "kind": "pallas_tiled_matmul",
                "m": m,
                "k": k,
                "n": n,
                "bm": bm,
                "bk": bk,
                "bn": bn,
                "batch": 1,
                "vmem_bytes": vmem_footprint_bytes(bm, bk, bn),
                "mxu_utilization": mxu_utilization_estimate(bm, bk, bn),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    # one reference graph per distinct problem size, for numeric cross-check
    for m, k, n in sorted({(m, k, n) for m, k, n, *_ in VARIANTS}):
        name = f"matmul_ref_{m}x{k}x{n}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lower_ref(m, k, n))
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": os.path.basename(path),
                "kind": "jnp_ref_matmul",
                "m": m,
                "k": k,
                "n": n,
                "bm": 0,
                "bk": 0,
                "bn": 0,
                "batch": 1,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for b, m, k, n, bm, bk, bn in BATCHED_VARIANTS:
        name = f"matmul_batched{b}_{m}x{k}x{n}_b{bm}x{bk}x{bn}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lower_batched(b, m, k, n, bm, bk, bn))
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": os.path.basename(path),
                "kind": "pallas_tiled_matmul_batched",
                "m": m,
                "k": k,
                "n": n,
                "bm": bm,
                "bk": bk,
                "bn": bn,
                "batch": b,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")

    # TSV twin for the Rust loader (no JSON dependency on the Rust side):
    # name file kind m k n bm bk bn batch
    tpath = os.path.join(out_dir, "manifest.tsv")
    with open(tpath, "w") as f:
        for a in manifest["artifacts"]:
            f.write(
                "\t".join(
                    str(a[c])
                    for c in [
                        "name",
                        "file",
                        "kind",
                        "m",
                        "k",
                        "n",
                        "bm",
                        "bk",
                        "bn",
                        "batch",
                    ]
                )
                + "\n"
            )
    print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
