"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1's ref).

Every Pallas kernel in this package has a reference implementation here;
pytest asserts allclose between the two across shapes/dtypes (including
hypothesis sweeps). The references are also lowered to HLO by aot.py so the
Rust side can cross-check numerics end to end.
"""

import jax.numpy as jnp


def matmul(x, y):
    """Dense matmul oracle: (m,k) @ (k,n) -> (m,n), f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def matmul_f64_acc(x, y):
    """Higher-precision accumulation variant used to bound kernel error."""
    return jnp.matmul(x.astype(jnp.float64), y.astype(jnp.float64)).astype(
        jnp.float32
    )
