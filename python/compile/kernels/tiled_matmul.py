"""Layer 1: the tiled-matmul Pallas kernel.

The paper's hot spot is the tiled matrix multiplication; its insight —
shape tiles by the memory system's native structure, not by round numbers —
maps to Pallas as the ``BlockSpec`` HBM↔VMEM schedule (DESIGN.md
§Hardware-Adaptation):

* the paper tiles the operand index space by the cache's associativity
  lattice so each tile occupies at most ``K−1`` slots of any cache set;
* here the L3 planner chooses block shapes ``(bm, bk, bn)`` so the three
  VMEM-resident blocks fit the VMEM budget, aligned to the VPU/MXU native
  ``(8, 128)`` / ``128×128`` tiling — the TPU's analog of "the hardware's
  natural lattice".

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that both pytest and
the Rust runtime can run. Real-TPU performance is *estimated* analytically
in DESIGN.md §Perf / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k_steps: int):
    """Grid-blocked matmul body.

    Grid = (m/bm, n/bn, k/bk) with k innermost; the output block is
    revisited across the k steps and accumulates in place (zeroed at the
    first step). This is the canonical Pallas accumulation pattern and the
    direct analog of the paper's "tile slices" reusing the output block
    while streaming the reduction dimension.
    """
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def tiled_matmul(x, y, *, bm: int = 64, bk: int = 64, bn: int = 64):
    """``(m,k) @ (k,n) -> (m,n)`` with explicit VMEM block shapes.

    Requires ``m % bm == k % bk == n % bn == 0`` (the L2 model pads).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})"
    )
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_kernel, n_k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def vmem_footprint_bytes(bm: int, bk: int, bn: int, bytes_per_elem: int = 4) -> int:
    """Analytic VMEM usage of one grid step: the three resident blocks.

    Used by DESIGN.md §Perf to check each variant against the ~16 MiB/core
    budget, and by the L3 planner to reject oversized tile requests — the
    TPU-side analog of the paper's "K−1 lattice points per set" capacity
    rule.
    """
    return bytes_per_elem * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(bm: int, bk: int, bn: int) -> float:
    """Fraction of MXU 128×128×128 macro-ops that carry real data.

    Blocks aligned to multiples of 128 (and ≥8 in the sublane dim) fill
    the systolic array; smaller blocks pad. This is the structural
    utilization estimate recorded in EXPERIMENTS.md §Perf (interpret-mode
    wallclock is NOT a TPU proxy).
    """
    def eff(b, native):
        pad = -b % native
        return b / (b + pad)

    return eff(bm, 128) * eff(bk, 128) * eff(bn, 128)
