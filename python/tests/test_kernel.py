"""Layer-1 correctness: Pallas kernel vs pure-jnp oracle.

This is the core numeric signal for the whole stack — the Rust runtime
executes exactly what these tests validate (the same HLO the kernel lowers
to). Fixed cases pin the shipped variants; hypothesis sweeps shapes,
blocks and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.tiled_matmul import (
    mxu_utilization_estimate,
    tiled_matmul,
    vmem_footprint_bytes,
)
from compile import model


def rand(shape, seed):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), shape, dtype=jnp.float32, minval=-1.0, maxval=1.0
    )


@pytest.mark.parametrize(
    "m,k,n,bm,bk,bn",
    [
        (64, 64, 64, 64, 64, 64),  # single block
        (128, 64, 64, 64, 64, 64),  # grid in m
        (64, 128, 64, 64, 64, 64),  # accumulation over k
        (64, 64, 128, 64, 64, 64),  # grid in n
        (256, 256, 256, 64, 64, 64),  # shipped variant
        (256, 256, 256, 128, 128, 128),  # shipped variant
        (96, 96, 96, 32, 32, 32),  # non-power-of-two grid
    ],
)
def test_kernel_matches_ref_fixed(m, k, n, bm, bk, bn):
    x, y = rand((m, k), 0), rand((k, n), 1)
    got = tiled_matmul(x, y, bm=bm, bk=bk, bn=bn)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_kernel_error_vs_f64_bounded():
    x, y = rand((128, 128), 2), rand((128, 128), 3)
    got = tiled_matmul(x, y, bm=32, bk=32, bn=32)
    exact = ref.matmul_f64_acc(x, y)
    np.testing.assert_allclose(got, exact, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    mb=st.integers(1, 4),
    kb=st.integers(1, 4),
    nb=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(mb, kb, nb, block, seed):
    m, k, n = mb * block, kb * block, nb * block
    x, y = rand((m, k), seed), rand((k, n), seed + 1)
    got = tiled_matmul(x, y, bm=block, bk=block, bn=block)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 100),
    k=st.integers(1, 100),
    n=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_model_pads_arbitrary_shapes(m, k, n, seed):
    x, y = rand((m, k), seed), rand((k, n), seed + 7)
    got = model.matmul(x, y, bm=32, bk=32, bn=32)
    want = ref.matmul(x, y)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_batched_model_matches_loop():
    xs = rand((4, 48, 40), 11)
    y = rand((40, 56), 12)
    got = model.batched_matmul(xs, y, bm=16, bk=16, bn=16)
    for b in range(4):
        np.testing.assert_allclose(
            got[b], ref.matmul(xs[b], y), rtol=1e-5, atol=1e-5
        )


def test_kernel_rejects_nondivisible():
    x, y = rand((65, 64), 0), rand((64, 64), 1)
    with pytest.raises(AssertionError):
        tiled_matmul(x, y, bm=64, bk=64, bn=64)


def test_vmem_footprint():
    # 64³ f32 blocks: 3 · 64·64·4 = 48 KiB — far under the 16 MiB budget
    assert vmem_footprint_bytes(64, 64, 64) == 3 * 64 * 64 * 4
    assert vmem_footprint_bytes(128, 128, 128) <= 16 * 2**20


def test_mxu_estimate_monotone():
    # 128-aligned blocks fully utilize; smaller blocks degrade
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(64, 64, 64) < 1.0
    assert (
        mxu_utilization_estimate(32, 32, 32)
        < mxu_utilization_estimate(64, 64, 64)
    )


def test_aot_variants_are_valid():
    """Every shipped AOT variant must be lowerable and block-divisible
    after padding (guards the manifest against bad configs)."""
    from compile.aot import BATCHED_VARIANTS, VARIANTS

    assert len(VARIANTS) >= 3
    for m, k, n, bm, bk, bn in VARIANTS:
        # model pads to block multiples; shipped variants should already
        # be aligned so the padded graph is pad-free
        assert m % bm == 0 and k % bk == 0 and n % bn == 0
        assert vmem_footprint_bytes(bm, bk, bn) <= 16 * 2**20
    for b, m, k, n, bm, bk, bn in BATCHED_VARIANTS:
        assert b >= 1 and m % bm == 0


def test_hlo_text_lowering_roundtrip():
    """The aot.py lowering path emits parseable HLO text with the expected
    entry computation (smoke test of the interchange format)."""
    from compile import aot

    lowered = aot.lower_variant(64, 64, 64, 32, 32, 32)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[64,64]" in text
