#!/usr/bin/env python3
"""Bench-regression guard: compare a BENCH_*.json smoke run against a
committed baseline and fail on regressions.

Usage:
    python3 python/check_bench.py <baseline.json> <current.json> [--tolerance 0.30]

Baseline/current entries come in two shapes, matching the two bench
emitters:

  hot_paths:   {"label": <mops>, ...}
  multilevel:  {"label": {"l1_misses": N, ..., "mops": X}, ...}

Rules (per named entry present in the baseline):
  * throughput ("mops" or a bare number): FAIL if current < (1 - tol) * baseline
  * miss counts / cycle estimates (keys ending in "_misses"/"_cycles"):
    deterministic simulation outputs — FAIL if current > (1 + tol) * baseline
  * ratio gates ('"ratio: <A> / <B>": floor'): the baseline value is a
    machine-independent floor on current[A] / current[B], with NO
    tolerance applied — FAIL if the measured ratio drops below it. This
    is how relative wins (e.g. coalesced-batch vs one-at-a-time serve
    throughput) are ratcheted without guessing absolute CI-host speeds.
  * a baseline value of 0 (or null) means "unseeded": skipped with a note,
    so mechanism and baselines can land before every number is ratcheted
  * a baseline entry missing from the current run FAILS (a silently
    renamed or dropped row would otherwise un-gate itself)
  * current entries not in the baseline are listed as candidates to commit

Exit status: 0 = pass, 1 = regression or structural mismatch.
"""

import argparse
import json
import sys


def classify(key):
    """'floor' for throughput-like values, 'ceiling' for cost-like ones."""
    if key.endswith("_misses") or key.endswith("_cycles"):
        return "ceiling"
    return "floor"


def check_value(label, key, base, cur, tol, failures, notes):
    if base is None or base == 0:
        notes.append(f"  unseeded  {label} [{key}] (baseline 0/null; current {cur})")
        return
    if classify(key) == "floor":
        limit = (1.0 - tol) * base
        if cur < limit:
            failures.append(
                f"  REGRESSION {label} [{key}]: {cur} < {limit:.1f} "
                f"(baseline {base}, -{tol:.0%} floor)"
            )
    else:
        limit = (1.0 + tol) * base
        if cur > limit:
            failures.append(
                f"  REGRESSION {label} [{key}]: {cur} > {limit:.1f} "
                f"(baseline {base}, +{tol:.0%} ceiling)"
            )


def check_ratio(label, floor, current, failures, notes):
    """'ratio: <A> / <B>' gate: current[A]/current[B] must be >= floor."""
    if floor is None or floor == 0:
        notes.append(f"  unseeded  {label} (baseline 0/null)")
        return
    spec = label[len("ratio: "):]
    parts = spec.split(" / ")
    if len(parts) != 2:
        failures.append(f"  SHAPE     {label}: expected 'ratio: <A> / <B>'")
        return
    a, b = parts
    missing = [k for k in (a, b) if not isinstance(current.get(k), (int, float))]
    if missing:
        failures.append(
            f"  MISSING   {label}: operand(s) {missing} absent from current run"
        )
        return
    if current[b] == 0:
        failures.append(f"  SHAPE     {label}: denominator {b!r} is 0")
        return
    ratio = current[a] / current[b]
    if ratio < floor:
        failures.append(
            f"  REGRESSION {label}: {ratio:.3f} < {floor} "
            f"({a}={current[a]}, {b}={current[b]}; no tolerance on ratio floors)"
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--tolerance", type=float, default=0.30)
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    failures, notes = [], []
    for label, base_val in baseline.items():
        if label.startswith("ratio: "):
            check_ratio(label, base_val, current, failures, notes)
            continue
        if label not in current:
            failures.append(f"  MISSING   {label}: in baseline but absent from current run")
            continue
        cur_val = current[label]
        if isinstance(base_val, dict):
            if not isinstance(cur_val, dict):
                failures.append(f"  SHAPE     {label}: baseline is an object, current is not")
                continue
            for key, b in base_val.items():
                if key not in cur_val:
                    failures.append(f"  MISSING   {label} [{key}]: absent from current run")
                    continue
                check_value(label, key, b, cur_val[key], args.tolerance, failures, notes)
        else:
            if isinstance(cur_val, dict):
                failures.append(f"  SHAPE     {label}: baseline is a number, current is not")
                continue
            check_value(label, "mops", base_val, cur_val, args.tolerance, failures, notes)

    new_entries = [k for k in current if k not in baseline]

    print(f"bench guard: {args.current} vs {args.baseline} (tolerance {args.tolerance:.0%})")
    for n in notes:
        print(n)
    if new_entries:
        print("  new entries (add to the baseline to start gating them):")
        for k in new_entries:
            print(f"    {json.dumps(k)}: {json.dumps(current[k])}")
    if failures:
        print(f"FAILED — {len(failures)} regression(s):")
        for f_ in failures:
            print(f_)
        return 1
    print(f"PASS — {len(baseline)} gated entries within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
